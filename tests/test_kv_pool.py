"""Property-test harness for the paged KV memory layer.

The allocator behind ``EngineConfig.kv_pool`` (``repro.serve.kv_pool``) is
pure host-side Python, so its invariants can be pinned exhaustively: random
admit/decode-grow/finish/evict schedules are generated (via the
``_hypothesis_compat`` shim — real hypothesis when installed, a seeded
deterministic grid otherwise) and the pool contract is checked after every
step:

1. free list + live pages partition ``{1, ..., num_pages - 1}``;
2. no page is owned by two non-sharing slots (multi-reference only ever
   means a shared prefix page, same content key);
3. refcounts hit zero exactly at release, never below;
4. the allocator is deterministic: a fixed schedule yields identical page
   assignments on every run.
"""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.serve.kv_pool import (KV_QUANT_BITS, KVBlockManager, KVPoolConfig,
                                 PagePool, TRASH_PAGE,
                                 contiguous_kv_bytes_per_token,
                                 paged_kv_bytes_per_token)


def _prompt(rng, lo=1, hi=24):
    return rng.randint(1, 100, int(rng.randint(lo, hi))).astype(np.int32)


def _run_schedule(seed, num_pages, page_size, prefix_sharing, steps=120,
                  check_every=1):
    """Drive a random admit/grow/finish schedule; invariant-check each
    step. Returns the manager plus the page-assignment trace (for the
    determinism property)."""
    rng = np.random.RandomState(seed)
    mgr = KVBlockManager(KVPoolConfig(num_pages=num_pages,
                                      page_size=page_size,
                                      prefix_sharing=prefix_sharing))
    live = []      # (alloc, pos)
    trace = []
    for step in range(steps):
        op = rng.randint(3)
        if op == 0:                                   # admit
            prompt = _prompt(rng)
            total = len(prompt) + int(rng.randint(1, 16))
            if mgr.pages_for(total) > mgr.usable_pages:
                with pytest.raises(ValueError):
                    mgr.admit(prompt, total)
            else:
                thr = float(rng.randint(3))
                a = mgr.admit(prompt, total, thr_key=thr)
                if a is not None:
                    mgr.register_prefix(prompt=prompt, alloc=a, thr_key=thr)
                    live.append([a, a.prompt_len])
                    trace.append(("admit", tuple(a.pages)))
        elif op == 1 and live:                        # grow one slot
            i = rng.randint(len(live))
            a, pos = live[i]
            if pos + 1 < a.total_tokens:
                grew = mgr.ensure(a, pos + 1)
                if grew:
                    live[i][1] = pos + 1
                    trace.append(("grow", tuple(a.pages)))
        elif op == 2 and live:                        # finish one slot
            i = rng.randint(len(live))
            a, _ = live.pop(i)
            mgr.release(a)
            trace.append(("release", tuple(a.pages)))
        if step % check_every == 0:
            mgr.check_invariants()
    for a, _ in live:
        mgr.release(a)
    mgr.check_invariants()
    return mgr, trace


class TestPagePool:
    def test_alloc_order_is_ascending(self):
        pool = PagePool(8)
        assert [pool.alloc_one() for _ in range(7)] == [1, 2, 3, 4, 5, 6, 7]
        assert pool.alloc_one() is None

    def test_trash_page_never_allocated(self):
        pool = PagePool(8)
        got = {pool.alloc_one() for _ in range(7)}
        assert TRASH_PAGE not in got

    def test_release_returns_page_lifo(self):
        pool = PagePool(8)
        pages = [pool.alloc_one() for _ in range(7)]
        pool.release(pages[2])
        pool.release(pages[5])
        assert pool.alloc_one() == pages[5]       # LIFO reuse
        assert pool.alloc_one() == pages[2]

    def test_refcount_zero_exactly_at_release(self):
        pool = PagePool(4)
        p = pool.alloc_one()
        pool.retain(p)
        pool.release(p)
        assert pool.refcount[p] == 1 and p not in pool.free_pages()
        pool.release(p)
        assert pool.refcount[p] == 0 and p in pool.free_pages()
        with pytest.raises(ValueError):
            pool.release(p)                       # never below zero

    def test_retain_free_page_rejected(self):
        pool = PagePool(4)
        with pytest.raises(ValueError):
            pool.retain(2)
        with pytest.raises(ValueError):
            pool.retain(TRASH_PAGE)


class TestInvariantSchedules:
    @settings(max_examples=12, deadline=None)
    @given(seed=st.integers(0, 10_000),
           num_pages=st.integers(4, 24),
           page_size=st.sampled_from([1, 2, 4, 8]),
           prefix_sharing=st.sampled_from([False, True]))
    def test_random_schedule_invariants(self, seed, num_pages, page_size,
                                        prefix_sharing):
        """Partition/refcount invariants hold after every step of a random
        admit/grow/finish schedule, sharing on or off."""
        _run_schedule(seed, num_pages, page_size, prefix_sharing)

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_allocator_deterministic(self, seed):
        """Same schedule -> byte-identical page-assignment trace."""
        _, t1 = _run_schedule(seed, 16, 4, True)
        _, t2 = _run_schedule(seed, 16, 4, True)
        assert t1 == t2

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 10_000),
           page_size=st.sampled_from([2, 4]))
    def test_no_cross_ownership(self, seed, page_size):
        """A page held by two live slots must be a shared prefix page of
        both (same position in both page lists, inside both shared
        regions) — exclusive tails never alias."""
        rng = np.random.RandomState(seed)
        mgr = KVBlockManager(KVPoolConfig(num_pages=32,
                                          page_size=page_size))
        base = rng.randint(1, 100, 4 * page_size).astype(np.int32)
        allocs = []
        for _ in range(5):
            prompt = np.concatenate(
                [base, _prompt(rng, 1, 2 * page_size)]).astype(np.int32)
            a = mgr.admit(prompt, len(prompt) + 4)
            if a is None:
                break
            mgr.register_prefix(prompt=prompt, alloc=a)
            allocs.append(a)
        assert len(allocs) >= 2, "pool sized to admit at least two"
        assert any(a.n_shared for a in allocs[1:]), "no sharing happened"
        for i, a in enumerate(allocs):
            for b in allocs[i + 1:]:
                for p in set(a.pages) & set(b.pages):
                    ia, ib = a.pages.index(p), b.pages.index(p)
                    assert ia == ib, \
                        f"page {p} aliased at different logical indices"
                    assert ib < b.n_shared, (
                        f"slot holds aliased page {p} outside its shared "
                        "prefix region")
        mgr.check_invariants()
        for a in allocs:
            mgr.release(a)
        mgr.check_invariants()

    def test_failed_admit_is_atomic(self):
        """An admission the pool cannot page leaves the pool byte-
        identical (no partial allocation to roll back)."""
        mgr = KVBlockManager(KVPoolConfig(num_pages=6, page_size=4,
                                          prefix_sharing=False))
        a = mgr.admit(np.arange(1, 16, dtype=np.int32), 18)   # 4+1 of 5
        assert a is not None
        before = (mgr.pool.free_pages(), list(mgr.pool.refcount))
        assert mgr.admit(np.arange(1, 9, dtype=np.int32), 10) is None
        assert (mgr.pool.free_pages(), list(mgr.pool.refcount)) == before
        assert mgr.stats.failed_admits == 1
        mgr.release(a)
        mgr.check_invariants()

    def test_failed_grow_is_atomic(self):
        mgr = KVBlockManager(KVPoolConfig(num_pages=4, page_size=4,
                                          prefix_sharing=False))
        a = mgr.admit(np.arange(1, 8, dtype=np.int32), 12)    # 2 of 3 pages
        b = mgr.admit(np.arange(1, 4, dtype=np.int32), 4)     # last page
        before = (mgr.pool.free_pages(), list(mgr.pool.refcount))
        assert not mgr.ensure(a, 8)           # third page: pool exhausted
        assert (mgr.pool.free_pages(), list(mgr.pool.refcount)) == before
        assert mgr.stats.grow_stalls == 1
        mgr.release(b)
        assert mgr.ensure(a, 8)               # resumes once pages free
        mgr.release(a)
        mgr.check_invariants()

    def test_never_fits_raises(self):
        mgr = KVBlockManager(KVPoolConfig(num_pages=4, page_size=4))
        with pytest.raises(ValueError, match="whole pool"):
            mgr.admit(np.arange(1, 10, dtype=np.int32), 16)    # 4 of 3


class TestPrefixSharing:
    def test_shared_pages_refcounted(self):
        """Two requests with a common full-page prefix share those pages;
        each page's refcount counts both slots plus the cache, and hits
        zero only after both release AND eviction."""
        ps = 4
        mgr = KVBlockManager(KVPoolConfig(num_pages=16, page_size=ps))
        base = np.arange(1, 1 + 2 * ps, dtype=np.int32)         # 2 full pages
        p1 = np.concatenate([base, [90, 91]]).astype(np.int32)
        p2 = np.concatenate([base, [80]]).astype(np.int32)
        a1 = mgr.admit(p1, len(p1) + 4)
        mgr.register_prefix(prompt=p1, alloc=a1)
        a2 = mgr.admit(p2, len(p2) + 4)
        assert a2.n_shared == 2 and a2.pages[:2] == a1.pages[:2]
        for p in a1.pages[:2]:
            assert mgr.pool.refcount[p] == 3      # slot1 + slot2 + cache
        mgr.release(a1)
        mgr.release(a2)
        for p in a2.pages[:2]:
            assert mgr.pool.refcount[p] == 1      # cache keeps them warm
        mgr.check_invariants()
        mgr.prefix.evict(2)
        for p in a2.pages[:2]:
            assert mgr.pool.refcount[p] == 0
        mgr.check_invariants()

    def test_partial_last_page_never_shared(self):
        """The page holding the first decode write is never handed out."""
        ps = 4
        mgr = KVBlockManager(KVPoolConfig(num_pages=16, page_size=ps))
        prompt = np.arange(1, 1 + ps + 2, dtype=np.int32)       # 1.5 pages
        a1 = mgr.admit(prompt, len(prompt) + 4)
        mgr.register_prefix(prompt=prompt, alloc=a1)
        a2 = mgr.admit(prompt, len(prompt) + 4)
        assert a2.n_shared == 1                   # only the full page
        assert a2.pages[0] == a1.pages[0] and a2.pages[1] != a1.pages[1]
        mgr.release(a1)
        mgr.release(a2)
        mgr.check_invariants()

    def test_thr_key_salts_the_chain(self):
        """KV content depends on the ODP threshold, so prefixes at
        different knob settings must not alias."""
        ps = 4
        mgr = KVBlockManager(KVPoolConfig(num_pages=16, page_size=ps))
        prompt = np.arange(1, 1 + 2 * ps, dtype=np.int32)
        a1 = mgr.admit(prompt, len(prompt) + 2, thr_key=0.0)
        mgr.register_prefix(prompt=prompt, alloc=a1, thr_key=0.0)
        a2 = mgr.admit(prompt, len(prompt) + 2, thr_key=0.5)
        assert a2.n_shared == 0
        a3 = mgr.admit(prompt, len(prompt) + 2, thr_key=0.0)
        assert a3.n_shared == 2
        for a in (a1, a2, a3):
            mgr.release(a)
        mgr.check_invariants()

    def test_eviction_frees_deepest_first(self):
        """Pool pressure evicts cache-only pages, chain tails before
        heads, and never pages a live slot still shares."""
        ps = 2
        mgr = KVBlockManager(KVPoolConfig(num_pages=8, page_size=ps))
        prompt = np.arange(1, 1 + 3 * ps, dtype=np.int32)       # 3 full pages
        a1 = mgr.admit(prompt, len(prompt) + 1)                 # 4 pages
        mgr.register_prefix(prompt=prompt, alloc=a1)
        mgr.release(a1)                           # 3 cache-only + 1 free
        assert mgr.num_free == 4                  # page 4 freed, 1-3 cached
        a2 = mgr.admit(np.arange(50, 62, dtype=np.int32), 13)   # needs 7
        assert a2 is not None and mgr.stats.evicted_pages >= 2
        mgr.check_invariants()
        mgr.release(a2)
        mgr.check_invariants()


class TestTableRow:
    def test_row_pads_with_trash(self):
        mgr = KVBlockManager(KVPoolConfig(num_pages=8, page_size=4))
        a = mgr.admit(np.arange(1, 6, dtype=np.int32), 10)
        row = mgr.table_row(a, 6)
        assert row.dtype == np.int32 and row.shape == (6,)
        assert list(row[:len(a.pages)]) == a.pages
        assert all(row[len(a.pages):] == TRASH_PAGE)
        assert all(mgr.table_row(None, 6) == TRASH_PAGE)
        mgr.release(a)

    def test_double_release_rejected(self):
        mgr = KVBlockManager(KVPoolConfig(num_pages=8, page_size=4))
        a = mgr.admit(np.arange(1, 6, dtype=np.int32), 10)
        mgr.release(a)
        with pytest.raises(ValueError):
            mgr.release(a)


class TestConfigAndSizing:
    def test_config_validation(self):
        with pytest.raises(ValueError):
            KVPoolConfig(num_pages=1)
        with pytest.raises(ValueError):
            KVPoolConfig(num_pages=8, page_size=0)
        with pytest.raises(ValueError):
            KVPoolConfig(num_pages=8, quant="fp8")
        with pytest.raises(ValueError):
            KVPoolConfig(num_pages=8, prefill_chunk=0)
        assert KVPoolConfig(num_pages=8, quant="int4").bits == 4

    def test_bytes_per_token_halves_under_int4(self):
        """The analytic sizing the CI gate measures for real: int4 paged
        storage is under half of the contiguous bf16 row (int8 is not,
        once per-position scales are paid — which is why the gate pins
        int4)."""
        for nkv, h in [(4, 32), (8, 128), (2, 64)]:
            bf16 = contiguous_kv_bytes_per_token(nkv, h)
            assert paged_kv_bytes_per_token(nkv, h, "int4") <= 0.5 * bf16
            assert (paged_kv_bytes_per_token(nkv, h, "off")
                    == 2 * nkv * h * 2)
        assert set(KV_QUANT_BITS) == {"off", "int8", "int4"}


class TestSharedStatePool:
    """Refcounted content-addressed shared state (encdec CrossKV)."""

    def _pool(self, capacity=8):
        from repro.serve.kv_pool import SharedStatePool
        return SharedStatePool(capacity=capacity)

    def test_identical_inputs_share_one_entry(self):
        pool = self._pool()
        enc = np.random.RandomState(0).randn(16, 8).astype(np.float32)
        key = pool.key_of(enc)
        calls = []
        a = pool.acquire(key, lambda: calls.append(1) or "entry")
        b = pool.acquire(key, lambda: calls.append(1) or "entry2")
        assert a is b and len(calls) == 1           # one compute, shared
        assert pool.refcount(key) == 2
        assert pool.stats.misses == 1 and pool.stats.hits == 1
        pool.release(key)
        assert pool.refcount(key) == 1
        pool.release(key)
        assert pool.refcount(key) == 0              # exactly zero at release

    def test_release_below_zero_raises(self):
        pool = self._pool()
        key = pool.key_of(np.zeros(4, np.float32))
        pool.acquire(key, lambda: "x")
        pool.release(key)
        with pytest.raises(ValueError, match="unacquired"):
            pool.release(key)
        with pytest.raises(ValueError, match="unacquired"):
            pool.release(b"never-acquired-key!!")

    def test_distinct_inputs_never_alias(self):
        """Different encoder inputs — including same bytes at a different
        shape — get different keys and independent entries."""
        pool = self._pool()
        a = np.arange(12, dtype=np.float32).reshape(3, 4)
        b = np.arange(12, dtype=np.float32).reshape(4, 3)
        c = np.arange(12, dtype=np.float32).reshape(3, 4) + 1
        keys = {pool.key_of(x) for x in (a, b, c)}
        assert len(keys) == 3
        entries = [pool.acquire(pool.key_of(x), lambda x=x: x.copy())
                   for x in (a, b, c)]
        assert entries[0] is not entries[1] is not entries[2]
        np.testing.assert_array_equal(entries[0], a)
        np.testing.assert_array_equal(entries[1], b)

    def test_released_entries_cached_then_evicted_lru(self):
        pool = self._pool(capacity=2)
        keys = [pool.key_of(np.full(3, i, np.float32)) for i in range(3)]
        for i, k in enumerate(keys):
            pool.acquire(k, lambda i=i: i)
            pool.release(k)
        assert len(pool) == 2                       # oldest evicted
        calls = []
        pool.acquire(keys[0], lambda: calls.append(1) or 0)
        assert calls, "evicted entry must be recomputed"
        # a cached (refcount-0) entry revives without recompute
        pool.acquire(keys[2], lambda: calls.append(9) or 2)
        assert len(calls) == 1


class TestSaltedChains:
    """The prefix-cache hash chain folds in the admission salt (encoder
    input) and the prefix-token offset, so requests that differ only in
    encoder-side state never alias pages."""

    def test_salt_separates_identical_prompts(self):
        ps = 4
        mgr = KVBlockManager(KVPoolConfig(num_pages=16, page_size=ps))
        prompt = np.arange(1, 1 + 2 * ps, dtype=np.int32)
        a1 = mgr.admit(prompt, len(prompt) + 2, salt=b"encoder-A")
        mgr.register_prefix(prompt=prompt, alloc=a1, salt=b"encoder-A")
        a2 = mgr.admit(prompt, len(prompt) + 2, salt=b"encoder-B")
        assert a2.n_shared == 0
        a3 = mgr.admit(prompt, len(prompt) + 2, salt=b"encoder-A")
        assert a3.n_shared == 2
        for a in (a1, a2, a3):
            mgr.release(a)
        mgr.check_invariants()

    def test_prefix_tokens_offset_spans(self):
        """A vlm prompt's pages cover prefix embeddings + tokens; the
        same token prompt at a different prefix length must not alias,
        and same-prefix requests share full pages."""
        ps = 4
        mgr = KVBlockManager(KVPoolConfig(num_pages=16, page_size=ps))
        prompt = np.arange(1, 1 + ps, dtype=np.int32)
        a1 = mgr.admit(prompt, ps + len(prompt) + 2, prefix_tokens=ps)
        assert len(a1.pages) >= 2            # prefix page + prompt page
        assert a1.prefix_tokens == ps
        mgr.register_prefix(prompt=prompt, alloc=a1)
        a2 = mgr.admit(prompt, ps + len(prompt) + 2, prefix_tokens=ps)
        assert a2.n_shared == 2              # prefix page AND token page
        a3 = mgr.admit(prompt, len(prompt) + 2, prefix_tokens=0)
        assert a3.n_shared == 0
        for a in (a1, a2, a3):
            mgr.release(a)
        mgr.check_invariants()


class TestStatePoolLifetimes:
    """Dense state-pool row lifetimes for recurrent (hybrid/SSM) state:
    rows are overwritten per admission and zero-reset between scratch
    reuses without touching neighbouring rows."""

    def _bundle(self, batch):
        import jax
        import jax.numpy as jnp
        from repro.models.layers.attention import KVCache
        from repro.models.layers.ssm import SSMState
        conv = jnp.arange(batch * 2 * 3, dtype=jnp.float32
                          ).reshape(1, batch, 2, 3)
        h = jnp.ones((1, batch, 4, 2, 2), jnp.float32)
        kv = jnp.zeros((1, batch, 8, 2, 4), jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(8, dtype=jnp.int32),
                               (1, batch, 8))
        return {"ssm": SSMState(conv, h),
                "attn": KVCache(kv, kv, pos, False, None, None)}

    def test_insert_row_touches_one_row(self):
        import jax
        import jax.numpy as jnp
        from repro.serve import slot_state
        pool, one = self._bundle(3), self._bundle(1)
        one = jax.tree.map(lambda a: a * 0 + 7.0
                           if a.dtype == jnp.float32 else a, one)
        out = slot_state.insert_row(pool, one, 1)
        for leaf_out, leaf_in in zip(jax.tree.leaves(out),
                                     jax.tree.leaves(pool)):
            np.testing.assert_array_equal(
                np.asarray(leaf_out[:, 0]), np.asarray(leaf_in[:, 0]))
            np.testing.assert_array_equal(
                np.asarray(leaf_out[:, 2]), np.asarray(leaf_in[:, 2]))
        assert float(out["ssm"].conv[0, 1].min()) == 7.0
        assert float(out["ssm"].h[0, 1].max()) == 7.0

    def test_reset_recurrent_zeroes_only_ssm(self):
        from repro.serve import slot_state
        out = slot_state.reset_recurrent(self._bundle(2))
        assert float(np.abs(np.asarray(out["ssm"].conv)).max()) == 0.0
        assert float(np.abs(np.asarray(out["ssm"].h)).max()) == 0.0
        np.testing.assert_array_equal(np.asarray(out["attn"].pos),
                                      np.asarray(self._bundle(2)["attn"].pos))

    def test_void_attention_tail_voids_only_positions(self):
        from repro.serve import slot_state
        out = slot_state.void_attention_tail(self._bundle(2), 5)
        pos = np.asarray(out["attn"].pos)
        assert (pos[..., 5:] == -1).all() and (pos[..., :5] >= 0).all()
        conv = np.asarray(out["ssm"].conv)
        np.testing.assert_array_equal(
            conv, np.asarray(self._bundle(2)["ssm"].conv))

    def test_state_kind_bundles_per_family(self):
        from repro.configs import get_config
        from repro.serve import slot_state
        expect = {"mixtral-8x7b": ["attn_kv"],
                  "falcon-mamba-7b": ["ssm"],
                  "zamba2-1.2b": ["ssm", "attn_kv"],
                  "whisper-medium": ["attn_kv", "cross_kv"],
                  "paligemma-3b": ["attn_kv"]}
        for name, kinds in expect.items():
            cfg = get_config(name, smoke=True)
            spec = slot_state.SlotStateSpec.from_config(cfg)
            assert [k.name for k in spec.kinds] == kinds, name
            sizes = slot_state.state_bytes_per_slot(cfg, capacity=64)
            assert set(sizes) == set(kinds) and all(
                v > 0 for v in sizes.values()), name
