"""int8/int4 KV cache: exactness of scale folding + quality bounds.

The round-trip bounds here are asserted on **real captured KV** from a
smoke decode, against the tolerances pinned in ``repro.serve.kv_pool``
(``KV_QUANT_REL_TOL`` / ``KV_DECODE_REL_TOL``) — the same constants the
paged serving engine is gated on, so the tolerance used in serving is the
tolerance tested.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.layers.attention import (_kv_quantize, _pack_int4,
                                           _unpack_int4, attend)
from repro.models.model_registry import build_model
from repro.serve.kv_pool import KV_DECODE_REL_TOL, KV_QUANT_REL_TOL


class TestKVQuantMath:
    def test_scale_folding_exact(self):
        """attend(int8 K/V + folded scales) == attend(dequantized K/V)."""
        key = jax.random.PRNGKey(0)
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (2, 4, 8, 32))
        k = jax.random.normal(ks[1], (2, 16, 4, 32))
        v = jax.random.normal(ks[2], (2, 16, 4, 32))
        kq, ksc = _kv_quantize(k)
        vq, vsc = _kv_quantize(v)
        k_deq = kq.astype(jnp.float32) * ksc[..., None]
        v_deq = vq.astype(jnp.float32) * vsc[..., None]
        mask = jnp.tril(jnp.ones((4, 16), bool), k=12)
        ref, _ = attend(q, k_deq, v_deq, mask)
        out, _ = attend(q, kq, vq, mask, kscale=ksc, vscale=vsc)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)

    def test_quantize_roundtrip_error(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 4, 64))
        q, s = _kv_quantize(x)
        deq = q.astype(jnp.float32) * s[..., None]
        err = jnp.abs(deq - x).max()
        assert float(err) <= float(jnp.abs(x).max()) / 127 + 1e-6
        assert q.dtype == jnp.int8

    def test_int4_pack_roundtrip_exact(self):
        """Packing two int4 codes per byte loses nothing."""
        codes = jax.random.randint(jax.random.PRNGKey(2), (3, 5, 2, 16),
                                   -7, 8, dtype=jnp.int32).astype(jnp.int8)
        packed = _pack_int4(codes)
        assert packed.shape == (3, 5, 2, 8) and packed.dtype == jnp.int8
        np.testing.assert_array_equal(np.asarray(_unpack_int4(packed)),
                                      np.asarray(codes))


def _captured_kv():
    """Real K/V content from a smoke prefill (per attention layer)."""
    cfg = get_config("internlm2-1.8b", smoke=True).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0,
                              cfg.vocab_size)
    caches = model.init_caches(1, 16)
    _, caches, _ = model.forward(params, toks, caches=caches)
    out = []
    for c in caches:                       # per period slot
        out.append(np.asarray(c.k[:, :, :12], np.float32))
        out.append(np.asarray(c.v[:, :, :12], np.float32))
    return out


class TestCapturedKVBounds:
    """Round-trip error on captured KV stays inside the pinned serving
    tolerances, at both storage widths the paged pool offers."""

    @pytest.mark.parametrize("mode,bits", [("int8", 8), ("int4", 4)])
    def test_captured_roundtrip_within_pinned_tol(self, mode, bits):
        tol = KV_QUANT_REL_TOL[mode]
        for x in _captured_kv():
            q, s = _kv_quantize(jnp.asarray(x), bits)
            if bits == 4:
                q = _unpack_int4(_pack_int4(q))    # through paged storage
            deq = np.asarray(q.astype(jnp.float32) * s[..., None])
            rel = np.linalg.norm(deq - x) / max(np.linalg.norm(x), 1e-9)
            assert rel <= tol, (mode, rel)


@pytest.mark.slow
class TestKVQuantDecode:
    @pytest.mark.parametrize("arch", ["internlm2-1.8b", "gemma2-27b"])
    def test_decode_tracks_fp(self, arch):
        cfg = get_config(arch, smoke=True).replace(dtype="float32")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0,
                                  cfg.vocab_size)
        full, _, _ = model.forward(params, toks)
        model_q = build_model(cfg.replace(kv_quant=True))
        caches = model_q.init_caches(2, 10)
        outs = []
        for t in range(10):
            logits, caches = model_q.decode_step(
                params, caches, toks[:, t:t + 1], jnp.asarray(t, jnp.int32))
            outs.append(logits[:, 0])
        dec = jnp.stack(outs, axis=1)
        rel = float(jnp.linalg.norm(dec - full) / jnp.linalg.norm(full))
        assert rel < 0.05, rel

    def test_prefill_then_decode(self):
        cfg = get_config("internlm2-1.8b", smoke=True).replace(
            dtype="float32", kv_quant=True)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0,
                                  cfg.vocab_size)
        caches = model.init_caches(1, 16)
        _, caches, _ = model.forward(params, toks[:, :8], caches=caches)
        logits, caches = model.decode_step(params, caches, toks[:, 8:9],
                                           jnp.asarray(8, jnp.int32))
        assert bool(jnp.isfinite(logits).all())


@pytest.mark.slow
class TestPagedReadThrough:
    """The paged attention path (write-through page table, dequant on
    read) against the contiguous cache, token-by-token on real logits."""

    def _drive(self, model, params, toks, caches, table=None):
        outs = []
        for t in range(toks.shape[1]):
            pos = (jnp.asarray([t], jnp.int32) if table is not None
                   else jnp.asarray(t, jnp.int32))
            logits, caches = model.decode_step(
                params, caches, toks[:, t:t + 1], pos, kv_table=table)
            outs.append(logits[:, 0])
        return jnp.stack(outs, axis=1)

    @pytest.mark.parametrize("quant,tol",
                             [("off", 1e-5), ("int8", KV_DECODE_REL_TOL)])
    def test_paged_decode_tracks_contiguous(self, quant, tol):
        cfg = get_config("internlm2-1.8b", smoke=True).replace(
            dtype="float32")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0,
                                  cfg.vocab_size)
        ref = self._drive(model, params, toks, model.init_caches(1, 16))
        pool = model.init_paged_caches(8, 4, quant=quant)
        table = jnp.asarray(np.array([[1, 2, 3, 4]], np.int32))
        out = self._drive(model, params, toks, pool, table=table)
        rel = float(jnp.linalg.norm(out - ref) / jnp.linalg.norm(ref))
        assert rel <= tol, (quant, rel)
