"""int8 KV cache (beyond-paper): exactness of scale folding + quality."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.layers.attention import _kv_quantize, attend
from repro.models.model_registry import build_model


class TestKVQuantMath:
    def test_scale_folding_exact(self):
        """attend(int8 K/V + folded scales) == attend(dequantized K/V)."""
        key = jax.random.PRNGKey(0)
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (2, 4, 8, 32))
        k = jax.random.normal(ks[1], (2, 16, 4, 32))
        v = jax.random.normal(ks[2], (2, 16, 4, 32))
        kq, ksc = _kv_quantize(k)
        vq, vsc = _kv_quantize(v)
        k_deq = kq.astype(jnp.float32) * ksc[..., None]
        v_deq = vq.astype(jnp.float32) * vsc[..., None]
        mask = jnp.tril(jnp.ones((4, 16), bool), k=12)
        ref, _ = attend(q, k_deq, v_deq, mask)
        out, _ = attend(q, kq, vq, mask, kscale=ksc, vscale=vsc)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)

    def test_quantize_roundtrip_error(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 4, 64))
        q, s = _kv_quantize(x)
        deq = q.astype(jnp.float32) * s[..., None]
        err = jnp.abs(deq - x).max()
        assert float(err) <= float(jnp.abs(x).max()) / 127 + 1e-6
        assert q.dtype == jnp.int8


@pytest.mark.slow
class TestKVQuantDecode:
    @pytest.mark.parametrize("arch", ["internlm2-1.8b", "gemma2-27b"])
    def test_decode_tracks_fp(self, arch):
        cfg = get_config(arch, smoke=True).replace(dtype="float32")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0,
                                  cfg.vocab_size)
        full, _, _ = model.forward(params, toks)
        model_q = build_model(cfg.replace(kv_quant=True))
        caches = model_q.init_caches(2, 10)
        outs = []
        for t in range(10):
            logits, caches = model_q.decode_step(
                params, caches, toks[:, t:t + 1], jnp.asarray(t, jnp.int32))
            outs.append(logits[:, 0])
        dec = jnp.stack(outs, axis=1)
        rel = float(jnp.linalg.norm(dec - full) / jnp.linalg.norm(full))
        assert rel < 0.05, rel

    def test_prefill_then_decode(self):
        cfg = get_config("internlm2-1.8b", smoke=True).replace(
            dtype="float32", kv_quant=True)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0,
                                  cfg.vocab_size)
        caches = model.init_caches(1, 16)
        _, caches, _ = model.forward(params, toks[:, :8], caches=caches)
        logits, caches = model.decode_step(params, caches, toks[:, 8:9],
                                           jnp.asarray(8, jnp.int32))
        assert bool(jnp.isfinite(logits).all())
