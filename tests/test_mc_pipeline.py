"""End-to-end MC (PMQ + ODP) pipeline tests on a reduced Mixtral.

Validates the paper's qualitative claims at smoke scale:
* PMQ-compressed model stays close to the FP model (and the error grows as
  target bits shrink);
* mixed-precision beats uniform-low-bit at comparable budget;
* ODP prunes a meaningful fraction of expert activations with bounded
  logit drift; token protection reduces the drift.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytestmark = pytest.mark.slow


from repro.config import CompressionConfig
from repro.configs import get_config
from repro.core import pipeline
from repro.models.layers.moe import OdpRuntime
from repro.models.transformer import DecoderModel, MCRuntime


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("mixtral-8x7b", smoke=True).replace(
        dtype="float32", d_model=128, d_ff=128, moe_d_ff=128,
        num_experts=8, capacity_factor=4.0)
    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0,
                                cfg.vocab_size)
    ref_logits, _, _ = model.forward(params, tokens, scan=False)
    return cfg, model, params, tokens, ref_logits


def _compress(setup, target_bits, layout="uniform", group=32):
    cfg, model, params, tokens, _ = setup
    ccfg = CompressionConfig(enabled=True, target_bits=target_bits,
                             group_size=group, odp_enabled=True)
    record = pipeline.calibrate(model, params, tokens,
                                bit_choices=tuple(ccfg.bit_choices),
                                group_size=ccfg.group_size)
    cplan = pipeline.plan(record, ccfg, layout=layout)
    art = pipeline.apply(model, params, cplan, record)
    return art.params, art.runtime, art.report


def _rel_err(a, b):
    a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
    return float(np.linalg.norm(a - b) / (np.linalg.norm(b) + 1e-9))


class TestPMQ:
    def test_compress_and_forward_uniform_layout(self, setup):
        cfg, model, params, tokens, ref = setup
        qp, runtime, report = _compress(setup, 2.6)
        assert runtime.quant_meta is not None, "uniform layout must be scan-safe"
        logits, _, _ = model.forward(
            qp, tokens, scan=False,
            mc=MCRuntime(odp=None, quant_meta=runtime.quant_meta))
        assert bool(jnp.isfinite(logits).all())
        err = _rel_err(logits, ref)
        assert err < 0.5, f"2.6-bit PMQ drifted too far: {err}"

    def test_budget_respected(self, setup):
        _, runtime, report = _compress(setup, 2.5)
        assert report.avg_bits <= 2.5 + 1e-6
        assert report.avg_bits >= 1.5
        # compression accounting sane: ~2.5/16 of dense + scale overhead
        assert 0.75 < report.pmq.compression_ratio < 0.95

    def test_error_monotone_in_bits(self, setup):
        cfg, model, params, tokens, ref = setup
        errs = []
        for k in (2.9, 2.0, 1.3):
            qp, runtime, _ = _compress(setup, k)
            logits, _, _ = model.forward(
                qp, tokens, scan=False,
                mc=MCRuntime(odp=None, quant_meta=runtime.quant_meta))
            errs.append(_rel_err(logits, ref))
        assert errs[0] < errs[-1], errs

    def test_scan_and_loop_quantized_agree(self, setup):
        cfg, model, params, tokens, _ = setup
        qp, runtime, _ = _compress(setup, 2.6)
        mc_rt = MCRuntime(odp=None, quant_meta=runtime.quant_meta)
        l1, _, _ = model.forward(qp, tokens, scan=True, mc=mc_rt)
        l2, _, _ = model.forward(qp, tokens, scan=False, mc=mc_rt)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=2e-3, atol=2e-3)

    def test_per_layer_layout(self, setup):
        cfg, model, params, tokens, ref = setup
        qp, runtime, report = _compress(setup, 2.6, layout="per_layer")
        logits, _, _ = model.forward(
            qp, tokens, mc=dataclasses.replace(runtime, odp=None))
        assert bool(jnp.isfinite(logits).all())
        assert _rel_err(logits, ref) < 0.5


class TestODP:
    def test_pruning_reduces_activations(self, setup):
        cfg, model, params, tokens, ref = setup
        odp = OdpRuntime(threshold=0.45, protect_ratio=0.02,
                         capacity_scale=1.0)
        logits, _, aux = model.forward(
            params, tokens, scan=False, collect_aux=True,
            mc=MCRuntime(odp=odp, quant_meta=None))
        fracs = [a["odp_pruned_frac"] for a in aux["per_layer"]
                 if "odp_pruned_frac" in a]
        assert fracs, "no MoE layers saw ODP"
        mean_frac = float(np.mean([float(f) for f in fracs]))
        assert 0.0 < mean_frac < 0.5
        assert _rel_err(logits, ref) < 0.35

    def test_protection_reduces_drift(self, setup):
        cfg, model, params, tokens, ref = setup
        errs = {}
        for ratio in (0.0, 0.25):
            odp = OdpRuntime(threshold=0.8, protect_ratio=ratio,
                             capacity_scale=1.0)
            logits, _, _ = model.forward(
                params, tokens, scan=False,
                mc=MCRuntime(odp=odp, quant_meta=None))
            errs[ratio] = _rel_err(logits, ref)
        assert errs[0.25] <= errs[0.0] + 1e-6, errs

    def test_calibrated_runtime(self, setup):
        qp, runtime, report = _compress(setup, 2.6)
        assert runtime.odp is not None
        assert 0.0 < runtime.odp.threshold < 1.0
        assert 0.0 < report.odp_prune_rate <= 0.5
        assert 0.5 < report.capacity_scale <= 1.0

    def test_full_mc_stack(self, setup):
        """PMQ + ODP together (the paper's headline configuration)."""
        cfg, model, params, tokens, ref = setup
        qp, runtime, report = _compress(setup, 2.6)
        logits, _, _ = model.forward(qp, tokens, scan=False, mc=runtime)
        assert bool(jnp.isfinite(logits).all())
        assert _rel_err(logits, ref) < 0.6
