"""Per-architecture smoke tests: reduced same-family configs, one forward
(+ one decode step where the family has one) on CPU; output shapes + finite.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config
from repro.models.model_registry import build_model

BATCH, SEQ = 2, 16


def _run_forward(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (BATCH, SEQ), 0,
                                cfg.vocab_size)
    kwargs = {}
    if cfg.family == "encdec":
        kwargs["enc_frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (BATCH, cfg.encoder_seq, cfg.d_model))
    if cfg.family == "vlm":
        kwargs["prefix_embeds"] = jax.random.normal(
            jax.random.PRNGKey(3), (BATCH, cfg.num_prefix_tokens,
                                    cfg.d_model))
    logits, _, aux = model.forward(params, tokens, **kwargs)
    return cfg, model, params, logits, kwargs


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg, model, params, logits, kwargs = _run_forward(arch)
    extra = cfg.num_prefix_tokens if cfg.family == "vlm" else 0
    assert logits.shape == (BATCH, SEQ + extra, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"


@pytest.mark.slow
@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_no_nans(arch):
    """One SGD step on the smoke config: finite loss and gradients."""
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (BATCH, SEQ), 0,
                                cfg.vocab_size)
    kwargs = {}
    if cfg.family == "encdec":
        kwargs["enc_frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (BATCH, cfg.encoder_seq, cfg.d_model))
    if cfg.family == "vlm":
        kwargs["prefix_embeds"] = jax.random.normal(
            jax.random.PRNGKey(3), (BATCH, cfg.num_prefix_tokens,
                                    cfg.d_model))

    def loss_fn(p):
        logits, _, aux = model.forward(p, tokens, **kwargs)
        logits = logits[:, -SEQ:]
        targets = jnp.roll(tokens, -1, axis=1)
        lp = jax.nn.log_softmax(logits.astype(jnp.float32))
        nll = -jnp.take_along_axis(lp, targets[..., None], -1).mean()
        for k, v in aux.items():
            if "load_balance" in k:
                nll = nll + 0.01 * v
        return nll

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss)), arch
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat), arch
    # gradient actually flows into the first layer stack
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in flat)
    assert gnorm > 0


DECODE_ARCHS = [a for a in ALL_ARCHS if a != "whisper-medium"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_step(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    caches = model.init_caches(BATCH, capacity=32)
    tok = jnp.zeros((BATCH, 1), jnp.int32)
    logits, caches = model.decode_step(params, caches, tok,
                                       jnp.asarray(0, jnp.int32))
    logits2, caches = model.decode_step(params, caches, tok + 1,
                                        jnp.asarray(1, jnp.int32))
    assert logits.shape == (BATCH, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits2).all())


def test_whisper_decode_with_cross_kv():
    cfg = get_config("whisper-medium", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    frames = jax.random.normal(jax.random.PRNGKey(1),
                               (BATCH, cfg.encoder_seq, cfg.d_model))
    enc_out = model.encode(params, frames)
    cross = model.cross_kv(params, enc_out)
    caches = model.init_caches(BATCH, capacity=32)
    tok = jnp.zeros((BATCH, 1), jnp.int32)
    logits, caches = model.decode_step(params, caches, tok,
                                       jnp.asarray(0, jnp.int32), cross=cross)
    assert logits.shape == (BATCH, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "internlm2-1.8b",
                                  "zamba2-1.2b", "falcon-mamba-7b"])
def test_scan_matches_loop(arch):
    """scan-over-layers and python-loop paths agree numerically."""
    cfg = get_config(arch, smoke=True).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (BATCH, SEQ), 0,
                                cfg.vocab_size)
    l1, _, _ = model.forward(params, tokens, scan=True)
    l2, _, _ = model.forward(params, tokens, scan=False)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=2e-4,
                               atol=2e-4)


def test_decode_matches_forward_mixtral():
    """Teacher-forced decode equals full forward (KV-cache correctness)."""
    cfg = get_config("mixtral-8x7b", smoke=True).replace(
        dtype="float32", capacity_factor=8.0)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                                cfg.vocab_size)
    full, _, _ = model.forward(params, tokens)
    caches = model.init_caches(1, capacity=8)
    outs = []
    for t in range(8):
        logits, caches = model.decode_step(params, caches, tokens[:, t:t + 1],
                                           jnp.asarray(t, jnp.int32))
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), rtol=2e-3,
                               atol=2e-3)


def test_decode_matches_forward_ssm():
    cfg = get_config("falcon-mamba-7b", smoke=True).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                                cfg.vocab_size)
    full, _, _ = model.forward(params, tokens)
    caches = model.init_caches(1, capacity=8)
    outs = []
    for t in range(8):
        logits, caches = model.decode_step(params, caches, tokens[:, t:t + 1],
                                           jnp.asarray(t, jnp.int32))
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), rtol=2e-3,
                               atol=2e-3)
