"""shard_map EP MoE vs GSPMD gather path — multi-device equivalence.

Runs in a subprocess with 8 host devices (mesh 4x2: EP/data=4, TP/model=2)
so the main test process keeps its single device.
"""
import subprocess
import sys
import textwrap
from pathlib import Path
import pytest

pytestmark = pytest.mark.slow


ROOT = Path(__file__).resolve().parents[1]

_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, {src!r})
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.models.layers import moe as moe_lib
    from repro.sharding.moe_parallel import apply_moe_shard_map
    from repro.sharding import context as shctx

    cfg = get_config("mixtral-8x7b", smoke=True).replace(
        dtype="float32", d_model=64, moe_d_ff=64, num_experts=8,
        capacity_factor=8.0)   # high cf: no drops on either path
    key = jax.random.PRNGKey(0)
    p = moe_lib.init_moe(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 64))

    # reference: single-device gather path
    y_ref, aux = moe_lib.apply_moe(p, x, cfg)

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    shctx.set_mesh_axes(("data", "model"), (4, 2))
    with shctx.activate_mesh(mesh):
        y_ep = jax.jit(lambda p_, x_: apply_moe_shard_map(
            p_, x_, cfg, mesh))(p, x)
    err = float(jnp.abs(y_ep - y_ref).max())
    rel = float(jnp.linalg.norm(y_ep - y_ref) / jnp.linalg.norm(y_ref))
    assert rel < 2e-3, (rel, err)
    print("EP_OK", rel)

    # ODP integration: pruning reduces, protection restores
    from repro.models.layers.moe import OdpRuntime
    odp = OdpRuntime(threshold=0.9, protect_ratio=0.0, capacity_scale=1.0)
    with shctx.activate_mesh(mesh):
        y_odp = jax.jit(lambda p_, x_: apply_moe_shard_map(
            p_, x_, cfg, mesh, odp=odp))(p, x)
    d_odp = float(jnp.linalg.norm(y_odp - y_ref) / jnp.linalg.norm(y_ref))
    assert d_odp > 1e-6  # pruning changed something
    print("EP_ODP_OK", d_odp)

    # collectives are the textbook schedule: 2 a2a + 1 ar per layer
    with shctx.activate_mesh(mesh):
        hlo = jax.jit(lambda p_, x_: apply_moe_shard_map(
            p_, x_, cfg, mesh)).lower(p, x).compile().as_text()
    n_a2a = hlo.count(" all-to-all(")
    assert n_a2a >= 2, n_a2a
    print("EP_COLLECTIVES_OK", n_a2a)
""")


def test_shard_map_ep_equivalence():
    out = subprocess.run(
        [sys.executable, "-c", _PROG.format(src=str(ROOT / "src"))],
        capture_output=True, text=True, timeout=600)
    assert "EP_OK" in out.stdout, out.stderr[-3000:]
    assert "EP_ODP_OK" in out.stdout, out.stderr[-3000:]
    assert "EP_COLLECTIVES_OK" in out.stdout, out.stderr[-3000:]
