"""shard_map EP MoE vs GSPMD gather path — multi-device equivalence.

Runs in a subprocess with 8 host devices (mesh 4x2: EP/data=4, TP/model=2)
so the main test process keeps its single device.
"""
import subprocess
import sys
import textwrap
from pathlib import Path
import pytest

pytestmark = pytest.mark.slow


ROOT = Path(__file__).resolve().parents[1]

_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, {src!r})
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.models.layers import moe as moe_lib
    from repro.sharding.moe_parallel import apply_moe_shard_map
    from repro.sharding import context as shctx

    cfg = get_config("mixtral-8x7b", smoke=True).replace(
        dtype="float32", d_model=64, moe_d_ff=64, num_experts=8,
        capacity_factor=8.0)   # high cf: no drops on either path
    key = jax.random.PRNGKey(0)
    p = moe_lib.init_moe(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 64))

    # reference: single-device gather path
    y_ref, aux = moe_lib.apply_moe(p, x, cfg)

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    shctx.set_mesh_axes(("data", "model"), (4, 2))
    with shctx.activate_mesh(mesh):
        y_ep = jax.jit(lambda p_, x_: apply_moe_shard_map(
            p_, x_, cfg, mesh))(p, x)
    err = float(jnp.abs(y_ep - y_ref).max())
    rel = float(jnp.linalg.norm(y_ep - y_ref) / jnp.linalg.norm(y_ref))
    assert rel < 2e-3, (rel, err)
    print("EP_OK", rel)

    # ODP integration: pruning reduces, protection restores
    from repro.models.layers.moe import OdpRuntime
    odp = OdpRuntime(threshold=0.9, protect_ratio=0.0, capacity_scale=1.0)
    with shctx.activate_mesh(mesh):
        y_odp = jax.jit(lambda p_, x_: apply_moe_shard_map(
            p_, x_, cfg, mesh, odp=odp))(p, x)
    d_odp = float(jnp.linalg.norm(y_odp - y_ref) / jnp.linalg.norm(y_ref))
    assert d_odp > 1e-6  # pruning changed something
    print("EP_ODP_OK", d_odp)

    # collectives are the textbook schedule: 2 a2a + 1 ar per layer
    with shctx.activate_mesh(mesh):
        hlo = jax.jit(lambda p_, x_: apply_moe_shard_map(
            p_, x_, cfg, mesh)).lower(p, x).compile().as_text()
    n_a2a = hlo.count(" all-to-all(")
    assert n_a2a >= 2, n_a2a
    print("EP_COLLECTIVES_OK", n_a2a)
""")


def test_shard_map_ep_equivalence():
    out = subprocess.run(
        [sys.executable, "-c", _PROG.format(src=str(ROOT / "src"))],
        capture_output=True, text=True, timeout=600)
    assert "EP_OK" in out.stdout, out.stderr[-3000:]
    assert "EP_ODP_OK" in out.stdout, out.stderr[-3000:]
    assert "EP_COLLECTIVES_OK" in out.stdout, out.stderr[-3000:]


_PROG_QUANT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import sys; sys.path.insert(0, {src!r})
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.models.model_registry import build_model
    from repro.core import pipeline as pl
    from repro.core.pipeline import _make_layer_plan
    from repro.config import CompressionConfig
    from repro.serve.engine import Request, ServeEngine

    cfg = get_config("mixtral-8x7b", smoke=True).replace(
        dtype="float32", num_layers=2, d_model=128, d_ff=256,
        moe_d_ff=256, num_experts=8, vocab_size=256, capacity_factor=8.0,
        scan_layers=False)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ccfg = CompressionConfig(enabled=True, target_bits=2.5, group_size=32,
                             odp_enabled=False)
    rng = np.random.RandomState(7)
    calib = jnp.asarray(rng.randint(1, cfg.vocab_size, (4, 48)), jnp.int32)
    record = pl.calibrate(model, params, calib, bit_choices=(1, 2, 3),
                          group_size=32)
    plan = pl.plan(record, ccfg, layout="uniform")
    # force class counts divisible by the 2-way data axis (scan-safe)
    bits = np.array([1, 1, 2, 2, 2, 2, 3, 3])
    plan.layers = [_make_layer_plan(lp.layer, bits, lp.objective)
                   for lp in plan.layers]
    artifact = pl.apply(model, params, plan, record)
    assert artifact.metas[0].class_counts == (2, 4, 2)

    def reqs(seed=0):
        r = np.random.RandomState(seed)
        return [Request(uid=i,
                        prompt=r.randint(1, cfg.vocab_size, 12)
                               .astype(np.int32),
                        max_new_tokens=6) for i in range(4)]

    # gather-path reference engine (no mesh)
    eng = ServeEngine.from_artifact(model, artifact, batch_size=4)
    res_g = eng.run(reqs())

    # quantized shard_map EP engine on the simulated 2-device mesh
    mesh = jax.make_mesh((2, 1), ("data", "model"))
    eng2 = ServeEngine.from_artifact(model, artifact, mesh=mesh,
                                     ep_dispatch=True, batch_size=4)
    res_e = eng2.run(reqs())
    for a, b in zip(res_g, res_e):
        assert np.array_equal(a.tokens, b.tokens), (a.tokens, b.tokens)
    print("EP_QUANT_SERVE_OK")

    # indivisible class layout must fail loudly at engine boot
    bits_bad = np.array([1, 1, 1, 2, 2, 3, 3, 3])
    plan.layers = [_make_layer_plan(lp.layer, bits_bad, lp.objective)
                   for lp in plan.layers]
    art_bad = pl.apply(model, params, plan, record)
    try:
        ServeEngine.from_artifact(model, art_bad, mesh=mesh,
                                  ep_dispatch=True, batch_size=4)
    except ValueError as e:
        assert "divide" in str(e), e
        print("EP_QUANT_VALIDATE_OK")
""")


def test_shard_map_ep_quantized_serving():
    """Acceptance: ServeEngine.from_artifact(mesh=..., ep_dispatch=...)
    serves a compressed artifact token-identically to the gather path on
    a simulated 2-device mesh."""
    out = subprocess.run(
        [sys.executable, "-c", _PROG_QUANT.format(src=str(ROOT / "src"))],
        capture_output=True, text=True, timeout=600)
    assert "EP_QUANT_SERVE_OK" in out.stdout, out.stderr[-3000:]
    assert "EP_QUANT_VALIDATE_OK" in out.stdout, out.stderr[-3000:]
