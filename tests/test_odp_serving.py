"""Online Dynamic Pruning in the serving hot path (per-request knob).

The contract under test (ISSUE 6 acceptance criteria):

* ``odp="off"`` is **token-for-token identical** to serving the same
  params with an ODP-stripped runtime — the knob's zero-threshold path is
  bit-exact, not merely close;
* at the artifact-default threshold, pruning actually happens and the
  realized pruned fraction matches ``plan_odp``'s calibration prediction;
* protected tokens are never pruned, whatever the per-slot threshold;
* the knob is a jit *input*: serving any mix of per-request settings
  compiles the decode step exactly once;
* the deprecated ``Request`` fields warn, and the unified
  :class:`EngineConfig` surface rejects unknown keywords loudly.

The expert-parallel dispatch path is covered by the slow subprocess test
at the bottom (simulated multi-device mesh), mirroring
``tests/test_moe_parallel.py``.
"""
import dataclasses
import subprocess
import sys
import textwrap
import warnings
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import CompressionConfig
from repro.configs import get_config
from repro.core import odp as odp_lib
from repro.core import pipeline
from repro.models.transformer import DecoderModel
from repro.serve.engine import (EngineConfig, GenerationOptions, Request,
                                ServeEngine, StaticServeEngine)

ROOT = Path(__file__).resolve().parents[1]


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("mixtral-8x7b", smoke=True).replace(
        dtype="float32", num_layers=2, d_model=64, d_ff=64, moe_d_ff=64,
        num_experts=4, vocab_size=128, capacity_factor=4.0,
        scan_layers=False)
    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    calib = jax.random.randint(jax.random.PRNGKey(1), (2, 48), 0,
                               cfg.vocab_size)
    record = pipeline.calibrate(model, params, calib,
                                bit_choices=(1, 2, 3), group_size=32)
    ccfg = CompressionConfig(enabled=True, target_bits=2.5, group_size=32,
                             odp_enabled=True)
    cplan = pipeline.plan(record, ccfg, layout="uniform")
    artifact = pipeline.apply(model, params, cplan, record)
    assert artifact.runtime.odp is not None
    assert artifact.runtime.odp.ratio_quantiles   # serving ratio->mu map
    return cfg, model, params, calib, artifact


def _reqs(n=3, odp="default", max_new=5):
    return [Request(uid=i, prompt=np.arange(1 + i, 9 + i, dtype=np.int32),
                    options=GenerationOptions(max_new_tokens=max_new,
                                              odp=odp))
            for i in range(n)]


def _stripped(artifact):
    return dataclasses.replace(artifact.runtime, odp=None)


class TestOffIdentity:
    def test_engine_off_matches_odp_stripped_runtime(self, setup):
        """odp='off' must reproduce the pre-ODP engine token-for-token."""
        cfg, model, params, calib, artifact = setup
        eng_off = ServeEngine.from_artifact(model, artifact, batch_size=2,
                                            odp="off")
        eng_ref = ServeEngine(model, artifact.params, mc=_stripped(artifact),
                              batch_size=2)
        for a, b in zip(eng_off.run(_reqs()), eng_ref.run(_reqs())):
            np.testing.assert_array_equal(a.tokens, b.tokens)

    def test_per_request_off_overrides_engine_default(self, setup):
        """The engine defaults to pruning; a request can opt out and must
        land exactly on the no-ODP tokens."""
        cfg, model, params, calib, artifact = setup
        eng = ServeEngine.from_artifact(model, artifact, batch_size=2)
        eng_ref = ServeEngine(model, artifact.params, mc=_stripped(artifact),
                              batch_size=2)
        got = eng.run(_reqs(odp="off"))
        ref = eng_ref.run(_reqs())
        for a, b in zip(got, ref):
            np.testing.assert_array_equal(a.tokens, b.tokens)

    def test_static_engine_off_matches_stripped(self, setup):
        cfg, model, params, calib, artifact = setup
        eng_off = StaticServeEngine.from_artifact(model, artifact,
                                                  batch_size=3, odp="off")
        eng_ref = StaticServeEngine(model, artifact.params,
                                    mc=_stripped(artifact), batch_size=3)
        for a, b in zip(eng_off.run(_reqs()), eng_ref.run(_reqs())):
            np.testing.assert_array_equal(a.tokens, b.tokens)


class TestPruningOn:
    def _fracs(self, model, artifact, tokens, thr):
        b = tokens.shape[0]
        _, _, aux = model.forward(
            artifact.params, tokens, scan=False, collect_aux=True,
            mc=artifact.runtime,
            odp_threshold=jnp.full((b,), thr, jnp.float32))
        return [float(a["odp_pruned_frac"]) for a in aux["per_layer"]
                if "odp_pruned_frac" in a]

    def test_pruned_fraction_matches_plan_prediction(self, setup):
        """Realized pruning at the calibrated threshold tracks the rate
        plan_odp predicted from the same calibration distribution."""
        cfg, model, params, calib, artifact = setup
        fracs = self._fracs(model, artifact, calib,
                            artifact.runtime.odp.threshold)
        assert fracs, "ODP aux missing from MoE layers"
        pred = artifact.report.odp_prune_rate
        assert pred > 0.05          # the default plan actually prunes
        assert abs(float(np.mean(fracs)) - pred) < 0.12, (fracs, pred)

    def test_ratio_knob_is_monotone(self, setup):
        """Explicit prune ratios map through the calibration quantiles:
        more requested pruning -> more realized pruning."""
        cfg, model, params, calib, artifact = setup
        odp = artifact.runtime.odp
        lo = odp_lib.threshold_for_prune_ratio(odp.ratio_quantiles, 0.2,
                                               cfg.top_k)
        hi = odp_lib.threshold_for_prune_ratio(odp.ratio_quantiles, 0.7,
                                               cfg.top_k)
        assert 0.0 <= lo <= hi
        f_lo = float(np.mean(self._fracs(model, artifact, calib, lo)))
        f_hi = float(np.mean(self._fracs(model, artifact, calib, hi)))
        f_0 = float(np.mean(self._fracs(model, artifact, calib, 0.0)))
        assert f_0 == 0.0
        assert f_lo <= f_hi
        assert f_hi > 0.1

    def test_protected_tokens_never_pruned(self):
        """Eq. 6 protection beats Eq. 5 pruning at any threshold — even a
        per-row traced threshold of ~1.0 (prune everything prunable)."""
        k = jax.random.PRNGKey(0)
        topw = jax.nn.softmax(jax.random.normal(k, (4, 16, 2)), axis=-1)
        topw = -jnp.sort(-topw, axis=-1)           # router emits descending
        imp = jax.random.uniform(jax.random.PRNGKey(1), (4, 16))
        protected = odp_lib.protect_tokens(imp, 0.25)
        # per-(row, token) traced threshold, as apply_moe broadcasts it
        thr = jnp.full((4, 16), 0.999, jnp.float32)
        keep = odp_lib.prune_mask(topw, thr, protected)
        assert bool(keep[protected].all())
        # and without protection that threshold does prune
        keep_raw = odp_lib.prune_mask(topw, thr)
        assert not bool(keep_raw.all())


class TestKnobIsJitInput:
    def test_no_retrace_across_knob_settings(self, setup):
        """off / default / explicit ratios — one compiled decode step."""
        cfg, model, params, calib, artifact = setup
        eng = ServeEngine.from_artifact(model, artifact, batch_size=3)
        eng.run(_reqs(odp="default"))
        eng.run(_reqs(odp="off"))
        eng.run(_reqs(odp=0.6))
        mixed = [Request(uid=i, prompt=np.arange(1, 8, dtype=np.int32),
                         options=GenerationOptions(max_new_tokens=4, odp=o))
                 for i, o in enumerate(("off", "default", 0.3))]
        eng.run(mixed)
        assert eng._decode._cache_size() == 1


class TestApiSurface:
    def test_deprecated_request_fields_warn(self):
        with pytest.warns(DeprecationWarning, match="max_new_tokens"):
            r = Request(uid=0, prompt=np.arange(4, dtype=np.int32),
                        max_new_tokens=3)
        assert r.opts.max_new_tokens == 3
        assert r.opts.odp == "default"

    def test_options_and_legacy_fields_conflict(self):
        with pytest.raises(ValueError, match="not both"):
            Request(uid=0, prompt=np.arange(4, dtype=np.int32),
                    max_new_tokens=3, options=GenerationOptions())

    def test_options_only_does_not_warn(self):
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            Request(uid=0, prompt=np.arange(4, dtype=np.int32),
                    options=GenerationOptions(max_new_tokens=3))

    def test_bad_odp_knob_rejected(self):
        with pytest.raises(ValueError, match="odp"):
            GenerationOptions(odp="sometimes")
        with pytest.raises(ValueError, match="prune ratio"):
            GenerationOptions(odp=1.5)

    def test_engine_config_unknown_kwarg_is_loud(self, setup):
        cfg, model, params, calib, artifact = setup
        with pytest.raises(TypeError, match="unknown engine option"):
            ServeEngine(model, artifact.params, mc=artifact.runtime,
                        batchsize=2)
        with pytest.raises(TypeError, match="unknown engine option"):
            StaticServeEngine.from_artifact(model, artifact, max_new=4)

    def test_explicit_ratio_without_odp_runtime_is_loud(self, setup):
        cfg, model, params, calib, artifact = setup
        eng = ServeEngine(model, artifact.params, mc=_stripped(artifact),
                          batch_size=2)
        with pytest.raises(ValueError, match="prune ratio"):
            eng.run(_reqs(n=1, odp=0.5))


# ------------------------------------------------- expert-parallel (slow)
_PROG_EP = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import sys; sys.path.insert(0, {src!r})
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.models.model_registry import build_model
    from repro.core import pipeline as pl
    from repro.core.pipeline import _make_layer_plan
    from repro.config import CompressionConfig
    from repro.serve.engine import (GenerationOptions, Request, ServeEngine)

    cfg = get_config("mixtral-8x7b", smoke=True).replace(
        dtype="float32", num_layers=2, d_model=128, d_ff=256,
        moe_d_ff=256, num_experts=8, vocab_size=256, capacity_factor=8.0,
        scan_layers=False)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ccfg = CompressionConfig(enabled=True, target_bits=2.5, group_size=32,
                             odp_enabled=True)
    rng = np.random.RandomState(7)
    calib = jnp.asarray(rng.randint(1, cfg.vocab_size, (4, 48)), jnp.int32)
    record = pl.calibrate(model, params, calib, bit_choices=(1, 2, 3),
                          group_size=32)
    plan = pl.plan(record, ccfg, layout="uniform")
    # force class counts divisible by the 2-way data axis (scan-safe)
    bits = np.array([1, 1, 2, 2, 2, 2, 3, 3])
    plan.layers = [_make_layer_plan(lp.layer, bits, lp.objective)
                   for lp in plan.layers]
    artifact = pl.apply(model, params, plan, record)
    assert artifact.runtime.odp is not None

    def reqs(odp="default", seed=0):
        r = np.random.RandomState(seed)
        return [Request(uid=i,
                        prompt=r.randint(1, cfg.vocab_size, 12)
                               .astype(np.int32),
                        options=GenerationOptions(max_new_tokens=6, odp=odp))
                for i in range(4)]

    mesh = jax.make_mesh((2, 1), ("data", "model"))

    # 1. pruning-on: quantized shard_map EP must match the gather path
    eng_g = ServeEngine.from_artifact(model, artifact, batch_size=4)
    res_g = eng_g.run(reqs())
    eng_e = ServeEngine.from_artifact(model, artifact, mesh=mesh,
                                      ep_dispatch=True, batch_size=4)
    res_e = eng_e.run(reqs())
    for a, b in zip(res_g, res_e):
        assert np.array_equal(a.tokens, b.tokens), (a.tokens, b.tokens)
    print("EP_ODP_ON_MATCHES_GATHER")
    # the first EP step may compile a second executable for the warm-up
    # sharding transition (host-committed inputs vs mesh-sharded caches);
    # the knob must not add to whatever that baseline is
    warm_cache = eng_e._decode._cache_size()

    # 2. off-identity on the EP path: odp='off' == ODP-stripped runtime
    res_off = eng_e.run(reqs(odp="off"))
    art2 = artifact
    art2.runtime = dataclasses.replace(artifact.runtime, odp=None)
    eng_s = ServeEngine.from_artifact(model, art2, mesh=mesh,
                                      ep_dispatch=True, batch_size=4)
    res_ref = eng_s.run(reqs())
    for a, b in zip(res_off, res_ref):
        assert np.array_equal(a.tokens, b.tokens), (a.tokens, b.tokens)
    print("EP_OFF_IDENTITY_OK")

    # 3. the knob never retraced the EP decode step: an explicit-ratio
    # run reuses the same compiled step traced during #1/#2
    eng_e.run(reqs(odp=0.5))
    assert eng_e._decode._cache_size() == warm_cache, (
        eng_e._decode._cache_size(), warm_cache)
    print("EP_NO_RETRACE_OK")
""")


@pytest.mark.slow
def test_ep_dispatch_odp_paths():
    out = subprocess.run(
        [sys.executable, "-c", _PROG_EP.format(src=str(ROOT / "src"))],
        capture_output=True, text=True, timeout=900)
    assert "EP_ODP_ON_MATCHES_GATHER" in out.stdout, out.stderr[-3000:]
    assert "EP_OFF_IDENTITY_OK" in out.stdout, out.stderr[-3000:]
    assert "EP_NO_RETRACE_OK" in out.stdout, out.stderr[-3000:]
