"""Staged compression API: calibrate -> plan -> apply -> CompressedArtifact.

Fast-slice tests (PR-gating): plan/artifact round-trips must hold — a saved
artifact must serve token-for-token identically to the in-memory one, for
both the scan-safe and the heterogeneous per-layer layouts, and re-planning
at a new bit-width must never re-run the calibration probes.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import CompressionConfig
from repro.configs import get_config
from repro.core import mc as mc_lib
from repro.core import pipeline
from repro.core import pmq as pmq_lib
from repro.models.transformer import DecoderModel, MCRuntime
from repro.serve.engine import Request, ServeEngine


def _ccfg(target_bits, **kw):
    kw.setdefault("group_size", 32)
    return CompressionConfig(enabled=True, target_bits=target_bits,
                             odp_enabled=True, **kw)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("mixtral-8x7b", smoke=True).replace(
        dtype="float32", num_layers=2, d_model=64, d_ff=64, moe_d_ff=64,
        num_experts=4, vocab_size=128, capacity_factor=4.0,
        scan_layers=False)
    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    calib = jax.random.randint(jax.random.PRNGKey(1), (2, 48), 0,
                               cfg.vocab_size)
    record = pipeline.calibrate(model, params, calib,
                                bit_choices=(1, 2, 3), group_size=32)
    return cfg, model, params, calib, record


@pytest.fixture(scope="module")
def uniform_artifact(setup):
    cfg, model, params, calib, record = setup
    plan = pipeline.plan(record, _ccfg(2.5), layout="uniform")
    return pipeline.apply(model, params, plan, record)


def _hetero_plan(record):
    """A genuinely heterogeneous plan: hand-edit layer 1's allocation (plans
    are data) so its class structure differs from layer 0's."""
    plan = pipeline.plan(record, _ccfg(2.5), layout="per_layer")
    bits0 = np.asarray(plan.layers[0].bits)
    bits1 = np.array([1, 2, 3, 3], np.int64)
    if np.array_equal(np.sort(bits0), np.sort(bits1)):
        bits1 = np.array([1, 1, 3, 3], np.int64)
    plan.layers[1] = pipeline._make_layer_plan(
        plan.layers[1].layer, bits1, 0.0)
    assert not plan.scan_safe
    return plan


def _generate(model, artifact, n_req=2, max_new=4):
    eng = ServeEngine.from_artifact(model, artifact, batch_size=2)
    reqs = [Request(uid=i, prompt=np.arange(1 + i, 9 + i, dtype=np.int32),
                    max_new_tokens=max_new) for i in range(n_req)]
    return [r.tokens for r in eng.run(reqs)]


class TestReplan:
    def test_replan_skips_probes(self, setup, monkeypatch):
        """Re-planning at a new target from a cached record must not
        re-invoke the eps probes (or any weight-touching stage)."""
        cfg, model, params, calib, record = setup
        assert record.eps_probe_runs == 1

        def boom(*a, **k):
            raise AssertionError("eps probes re-ran during plan()")
        monkeypatch.setattr(pmq_lib, "compute_eps", boom)
        p_low = pipeline.plan(record, _ccfg(2.54), layout="per_layer")
        p_high = pipeline.plan(record, _ccfg(3.0), layout="per_layer")
        assert p_high.achieved_bits > p_low.achieved_bits
        assert record.eps_probe_runs == 1

    def test_plan_requires_matching_probe_settings(self, setup):
        cfg, model, params, calib, record = setup
        with pytest.raises(ValueError, match="no eps table"):
            pipeline.plan(record, _ccfg(2.5, group_size=16))

    def test_ensure_eps_caches(self, setup):
        cfg, model, params, calib, record = setup
        runs = record.eps_probe_runs
        record.ensure_eps(model, params, (1, 2, 3), 32)  # cached key
        assert record.eps_probe_runs == runs


class TestPlanSerialization:
    def test_json_roundtrip(self, setup, tmp_path):
        cfg, model, params, calib, record = setup
        plan = pipeline.plan(record, _ccfg(2.5), layout="uniform")
        path = plan.save(tmp_path / "plan.json")
        assert pipeline.CompressionPlan.load(path) == plan

    def test_plan_reports_predictions(self, setup):
        cfg, model, params, calib, record = setup
        plan = pipeline.plan(record, _ccfg(2.5), layout="uniform")
        assert plan.achieved_bits <= 2.5 + 1e-9
        assert 0 < plan.predicted_bytes < plan.original_bytes
        assert plan.uniform_achieved_bits is not None
        assert plan.odp is not None and 0 < plan.odp["threshold"] < 1


class TestArtifactRoundtrip:
    def test_scan_safe_roundtrip(self, setup, uniform_artifact, tmp_path):
        cfg, model, params, calib, record = setup
        art = uniform_artifact
        assert art.scan_safe and art.runtime.quant_meta is not None
        art.save(tmp_path / "art")
        loaded = pipeline.CompressedArtifact.load(tmp_path / "art")
        assert loaded.scan_safe
        assert loaded.plan == art.plan
        assert loaded.metas == art.metas
        l1, _, _ = model.forward(art.params, calib, mc=art.runtime)
        l2, _, _ = model.forward(loaded.params, calib, mc=loaded.runtime)
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
        for t1, t2 in zip(_generate(model, art),
                          _generate(model, loaded)):
            np.testing.assert_array_equal(t1, t2)

    def test_per_layer_roundtrip(self, setup, tmp_path):
        cfg, model, params, calib, record = setup
        plan = _hetero_plan(record)
        art = pipeline.apply(model, params, plan, record)
        assert not art.scan_safe
        assert art.runtime.layer_metas is not None
        assert "moe_layers" in art.params
        art.save(tmp_path / "art")
        loaded = pipeline.CompressedArtifact.load(tmp_path / "art")
        assert loaded.runtime.layer_metas == art.runtime.layer_metas
        l1, _, _ = model.forward(art.params, calib, mc=art.runtime)
        l2, _, _ = model.forward(loaded.params, calib, mc=loaded.runtime)
        assert bool(jnp.isfinite(l1).all())
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
        for t1, t2 in zip(_generate(model, art),
                          _generate(model, loaded)):
            np.testing.assert_array_equal(t1, t2)

    def test_fingerprint_mismatch_rejected(self, setup, uniform_artifact):
        cfg, model, params, calib, record = setup
        other = DecoderModel(cfg.replace(d_model=128, d_ff=128,
                                         moe_d_ff=128))
        with pytest.raises(ValueError, match="artifact/model mismatch"):
            ServeEngine.from_artifact(other, uniform_artifact)

    def test_plain_checkpoint_rejected(self, setup, tmp_path):
        from repro.checkpoint import checkpointer as ckpt_lib
        ckpt_lib.save_pytree(tmp_path / "ck", 0,
                             {"a": np.zeros(3, np.float32)})
        with pytest.raises(ValueError, match="not a CompressedArtifact"):
            pipeline.CompressedArtifact.load(tmp_path / "ck")


class TestPublicSurface:
    def test_monolithic_shims_removed(self):
        """compress()/quantized_forward() finished their deprecation
        cycle — the facade now only re-exports the staged API."""
        assert not hasattr(mc_lib, "compress")
        assert not hasattr(mc_lib, "quantized_forward")
        assert mc_lib.calibrate is pipeline.calibrate
        assert mc_lib.plan is pipeline.plan
        assert mc_lib.apply is pipeline.apply

    def test_package_root_reexports(self):
        import repro
        assert repro.calibrate is pipeline.calibrate
        assert repro.plan is pipeline.plan
        assert repro.apply is pipeline.apply
        assert repro.CompressedArtifact is pipeline.CompressedArtifact
        from repro.serve import engine as engine_lib
        assert repro.ServeEngine is engine_lib.ServeEngine
        assert repro.StaticServeEngine is engine_lib.StaticServeEngine
        assert repro.Request is engine_lib.Request
        assert repro.GenerationOptions is engine_lib.GenerationOptions
        assert repro.EngineConfig is engine_lib.EngineConfig
        with pytest.raises(AttributeError):
            repro.compress


class TestUniformCounts:
    def test_budget_not_silently_exceeded(self):
        """The old widest-class absorption could overshoot the budget the
        per-layer optima realized; the repaired counts must not."""
        layers = [np.array([1, 2, 2]), np.array([2, 2, 3])]
        counts, achieved = pmq_lib.uniform_counts(layers, (1, 2, 3))
        assert sum(counts) == 3
        budget = int(np.floor(np.mean([b.sum() for b in layers])))
        assert achieved * 3 <= budget + 1e-9
        assert achieved == sum(c * b for c, b in zip(counts, (1, 2, 3))) / 3

    def test_demotion_is_one_class_step(self):
        """When medians overshoot, demotion moves an expert one class down
        (not straight to the narrowest), landing as close to budget as
        possible."""
        layers = [np.array([2, 3, 3, 3]), np.array([1, 2, 3, 3]),
                  np.array([1, 1, 2, 2])]
        counts, achieved = pmq_lib.uniform_counts(layers, (1, 2, 3))
        assert counts == (1, 2, 1)          # (1,1,2) demoted 3->2, not 3->1
        assert achieved == pytest.approx(2.0)

    def test_exact_case_unchanged(self):
        layers = [np.array([1, 2, 3, 3]), np.array([1, 2, 3, 3])]
        counts, achieved = pmq_lib.uniform_counts(layers, (1, 2, 3))
        assert counts == (1, 1, 2)
        assert achieved == pytest.approx(2.25)

    def test_unsorted_bit_choices(self):
        """bit_choices carries no ordering guarantee; the repair must go by
        width, not by tuple position."""
        layers = [np.array([3, 3, 2, 2]), np.array([3, 2, 2, 1])]
        counts, achieved = pmq_lib.uniform_counts(layers, (3, 2, 1))
        assert sum(counts) == 4
        budget = int(np.floor(np.mean([b.sum() for b in layers])))
        assert achieved * 4 <= budget + 1e-9
        up_counts, up_achieved = pmq_lib.uniform_counts(
            layers, (1, 2, 3))
        assert counts == tuple(reversed(up_counts))
        assert achieved == pytest.approx(up_achieved)

    def test_clear_errors(self):
        with pytest.raises(ValueError, match="no per-layer allocations"):
            pmq_lib.uniform_counts([], (1, 2, 3))
        with pytest.raises(ValueError, match="disagree on expert count"):
            pmq_lib.uniform_counts([np.array([1, 2]), np.array([1, 2, 3])],
                                   (1, 2, 3))
