"""Unit + property tests for the quantization substrate."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.quant import (
    accumulate_hessian, binarize, binary_matmul_addsub, binary_quant_dequant,
    debinarize, dequantize, dequantize_packed, gptq_dequantize, gptq_quantize,
    init_hessian, pack_codes, pack_quantized, quant_dequant, quantization_mse,
    quantize, reconstruction_loss, rtn_quantize, unpack_codes,
)

jax.config.update("jax_enable_x64", False)


def _w(key, d_in=128, d_out=64):
    return jax.random.normal(jax.random.PRNGKey(key), (d_in, d_out)) * 0.05


# ---------------------------------------------------------------- quantizer
class TestQuantizer:
    @pytest.mark.parametrize("bits", [2, 3, 4, 8])
    def test_roundtrip_error_bounded(self, bits):
        w = _w(0)
        qp = quantize(w, bits, 32)
        wq = dequantize(qp)
        # error bounded by half an LSB per element
        g = w.reshape(-1, 32, w.shape[1])
        step = qp.scales[:, None, :]
        err = jnp.abs(g - wq.reshape(g.shape))
        assert jnp.all(err <= 0.5 * step + 1e-6)

    def test_codes_in_range(self):
        for bits in (2, 3, 4):
            qp = quantize(_w(1), bits, 32)
            assert int(qp.codes.max()) <= 2 ** bits - 1
            assert qp.codes.dtype == jnp.uint8

    def test_monotone_in_bits(self):
        w = _w(2)
        errs = [float(quantization_mse(w, b, 32)) for b in (2, 3, 4, 8)]
        assert errs == sorted(errs, reverse=True)

    def test_exact_at_high_bits(self):
        w = _w(3)
        assert float(quantization_mse(w, 8, 32)) < 1e-6


# ------------------------------------------------------------------ binary
class TestBinary:
    def test_sign_preserved(self):
        w = _w(4)
        bp = binarize(w, 32)
        wq = debinarize(bp)
        nz = jnp.abs(w) > 1e-6
        assert jnp.all(jnp.sign(wq)[nz] == jnp.sign(w)[nz])

    def test_per_tensor_matches_paper_scale(self):
        w = _w(5)
        bp = binarize(w, 32, per_tensor=True)
        assert np.isclose(float(bp.scales.reshape(())),
                          float(jnp.mean(jnp.abs(w))), rtol=1e-5)

    def test_addsub_equals_matmul(self):
        """Paper Eq. (10): add/sub form == dense matmul with dequant weights."""
        w = _w(6, 64, 32)
        x = jax.random.normal(jax.random.PRNGKey(7), (4, 64))
        for per_tensor in (True, False):
            bp = binarize(w, 16, per_tensor=per_tensor)
            ref = x @ debinarize(bp)
            out = binary_matmul_addsub(x, bp)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       rtol=2e-4, atol=2e-4)

    def test_grouped_beats_per_tensor(self):
        w = _w(8) * jnp.linspace(0.1, 3.0, 128)[:, None]  # heteroscedastic rows
        e_t = float(jnp.mean((w - binary_quant_dequant(w, 32, True)) ** 2))
        e_g = float(jnp.mean((w - binary_quant_dequant(w, 32, False)) ** 2))
        assert e_g < e_t


# ----------------------------------------------------------------- packing
class TestPacking:
    @pytest.mark.parametrize("bits", [1, 2, 3, 4, 8])
    def test_roundtrip_identity(self, bits):
        key = jax.random.PRNGKey(bits)
        codes = jax.random.randint(key, (64, 16), 0, 2 ** bits).astype(jnp.uint8)
        planes = pack_codes(codes, bits)
        out = unpack_codes(planes, bits, 64)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(codes))

    @pytest.mark.parametrize("bits", [1, 2, 3, 4])
    def test_packed_size(self, bits):
        codes = jnp.zeros((64, 16), jnp.uint8)
        planes = pack_codes(codes, bits)
        total_bytes = sum(int(np.prod(p.shape)) for p in planes)
        assert total_bytes == 64 * 16 * bits // 8

    @given(bits=st.sampled_from([1, 2, 3, 4]),
           d_in=st.sampled_from([8, 32, 128]),
           d_out=st.integers(1, 9),
           seed=st.integers(0, 2 ** 16))
    @settings(max_examples=25, deadline=None)
    def test_roundtrip_property(self, bits, d_in, d_out, seed):
        rng = np.random.RandomState(seed)
        codes = rng.randint(0, 2 ** bits, (d_in, d_out)).astype(np.uint8)
        out = unpack_codes(pack_codes(jnp.asarray(codes), bits), bits, d_in)
        np.testing.assert_array_equal(np.asarray(out), codes)

    @pytest.mark.parametrize("bits", [1, 2, 3, 4])
    def test_pack_dequant_matches_direct(self, bits):
        w = _w(9, 64, 16)
        res = rtn_quantize(w, bits=bits, group_size=32)
        pw = pack_quantized(res.codes, res.scales, res.zeros, bits, 32)
        np.testing.assert_allclose(
            np.asarray(dequantize_packed(pw, jnp.float32)),
            np.asarray(gptq_dequantize(res)), rtol=1e-5, atol=1e-6)


# -------------------------------------------------------------------- gptq
class TestGPTQ:
    def _calib(self, key, n=512, d_in=128):
        # correlated activations -> non-trivial Hessian
        k1, k2 = jax.random.split(jax.random.PRNGKey(key))
        basis = jax.random.normal(k1, (d_in, d_in)) / np.sqrt(d_in)
        z = jax.random.normal(k2, (n, d_in))
        return z @ basis

    def test_hessian_accumulation(self):
        x = self._calib(0)
        h, cnt = accumulate_hessian(init_hessian(128), x, 0)
        assert cnt == 512
        expected = 2.0 / 512 * (x.T @ x)
        np.testing.assert_allclose(np.asarray(h), np.asarray(expected),
                                   rtol=1e-4, atol=1e-5)
        # two-chunk accumulation == one-shot
        h2, c2 = accumulate_hessian(init_hessian(128), x[:256], 0)
        h2, c2 = accumulate_hessian(h2, x[256:], c2)
        np.testing.assert_allclose(np.asarray(h2), np.asarray(h), rtol=1e-4,
                                   atol=1e-5)

    @pytest.mark.parametrize("bits", [2, 3])
    def test_gptq_beats_rtn_on_hessian_objective(self, bits):
        """GPTQ must reduce the proxy loss tr(dW^T H dW) vs round-to-nearest."""
        w = _w(10)
        x = self._calib(11)
        h, _ = accumulate_hessian(init_hessian(128), x, 0)
        g = gptq_quantize(w, h, bits=bits, group_size=32)
        r = rtn_quantize(w, bits=bits, group_size=32)
        lg = float(reconstruction_loss(w, g, h))
        lr = float(reconstruction_loss(w, r, h))
        assert lg < lr, (lg, lr)

    def test_gptq_activation_mse_improves(self):
        """The actual Eq. 2 objective: ||XW - XW_q||^2 smaller for GPTQ."""
        w = _w(12)
        x = self._calib(13)
        h, _ = accumulate_hessian(init_hessian(128), x, 0)
        g = gptq_quantize(w, h, bits=2, group_size=32)
        r = rtn_quantize(w, bits=2, group_size=32)
        eg = float(jnp.mean((x @ w - x @ gptq_dequantize(g)) ** 2))
        er = float(jnp.mean((x @ w - x @ gptq_dequantize(r)) ** 2))
        assert eg < er, (eg, er)

    def test_gptq_1bit_runs_and_signs(self):
        w = _w(14)
        x = self._calib(15)
        h, _ = accumulate_hessian(init_hessian(128), x, 0)
        g = gptq_quantize(w, h, bits=1, group_size=32)
        assert set(np.unique(np.asarray(g.codes))) <= {0, 1}
        wq = gptq_dequantize(g)
        assert float(jnp.mean((w - wq) ** 2)) < float(jnp.mean(w ** 2))

    def test_gptq_high_bits_near_exact(self):
        w = _w(16)
        x = self._calib(17)
        h, _ = accumulate_hessian(init_hessian(128), x, 0)
        g = gptq_quantize(w, h, bits=8, group_size=32)
        err = float(jnp.mean((w - gptq_dequantize(g)) ** 2))
        assert err < 1e-6

    def test_identity_hessian_matches_rtn(self):
        """With H = I, GPTQ's per-row decisions equal RTN row rounding."""
        w = _w(18)
        h = jnp.eye(128)
        g = gptq_quantize(w, h, bits=4, group_size=32, percdamp=0.0)
        r = rtn_quantize(w, bits=4, group_size=32)
        # identical scales; codes may differ only where compensation shifted
        np.testing.assert_allclose(np.asarray(g.scales), np.asarray(r.scales),
                                   rtol=1e-5)
        frac_diff = np.mean(np.asarray(g.codes) != np.asarray(r.codes))
        assert frac_diff < 0.05
