"""Continuous-batching ServeEngine: token-for-token equivalence with
sequential generation, slot lifecycle, EOS stopping, and stats accounting.

The sequential reference below drives the model's prefill/decode steps
directly with scalar (shared) positions — the pre-continuous code path —
so equivalence also cross-checks the per-row-position cache insert against
the shared-position one.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.layers.moe import OdpRuntime
from repro.models.model_registry import build_model
from repro.models.transformer import MCRuntime
from repro.serve.engine import (Request, ServeEngine, StaticServeEngine)


def _mixtral():
    # high capacity factor: decode-time expert capacity never binds, so
    # routing is per-token independent and batching cannot change tokens
    cfg = get_config("mixtral-8x7b", smoke=True).replace(
        dtype="float32", num_layers=2, d_model=64, d_ff=128, moe_d_ff=128,
        vocab_size=256, capacity_factor=8.0, scan_layers=False)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _dense():
    cfg = get_config("internlm2-1.8b", smoke=True).replace(
        dtype="float32", num_layers=2, d_model=64, d_ff=128, vocab_size=256)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    return cfg, model, params


def _generate_sequential(model, params, prompt: np.ndarray, max_new: int,
                         mc=None):
    """Greedy generation, one request, scalar-position decode path."""
    lp = len(prompt)
    caches = model.init_caches(1, lp + max_new)
    logits, caches, _ = model.forward(
        params, jnp.asarray(prompt[None, :]), caches=caches, mc=mc)
    cur = int(jnp.argmax(logits[0, -1]))
    out = [cur]
    for t in range(max_new - 1):
        logits, caches = model.decode_step(
            params, caches, jnp.asarray([[cur]], jnp.int32),
            jnp.asarray(lp + t, jnp.int32), mc=mc)
        cur = int(jnp.argmax(logits[0, -1]))
        out.append(cur)
    return np.asarray(out, np.int32)


def _mixed_requests(cfg, n, seed=0):
    rng = np.random.RandomState(seed)
    return [Request(uid=i,
                    prompt=rng.randint(1, cfg.vocab_size,
                                       int(rng.randint(4, 20))
                                       ).astype(np.int32),
                    max_new_tokens=int(rng.randint(2, 9)))
            for i in range(n)]


class TestEquivalence:
    @pytest.mark.parametrize(
        "build",
        [pytest.param(_mixtral, id="moe", marks=pytest.mark.slow),
         pytest.param(_dense, id="dense")])
    def test_matches_sequential(self, build):
        """Pool of 3 slots, 6 queued mixed-length requests: every request's
        tokens must equal its one-request-at-a-time generation."""
        cfg, model, params = build()
        reqs = _mixed_requests(cfg, 6)
        eng = ServeEngine(model, params, batch_size=3)
        res = eng.run(reqs)
        assert [r.uid for r in res] == [r.uid for r in reqs]
        for req, r in zip(reqs, res):
            ref = _generate_sequential(model, params, req.prompt,
                                       req.max_new_tokens)
            np.testing.assert_array_equal(r.tokens, ref, err_msg=f"uid "
                                          f"{req.uid}")
            assert r.new_tokens == req.max_new_tokens

    def test_idle_slots_do_not_consume_expert_capacity(self):
        """Tight capacity_factor, one live request in a pool of 4: the
        idle slots' junk tokens are masked out of MoE dispatch, so tokens
        must still match sequential generation exactly."""
        cfg = get_config("mixtral-8x7b", smoke=True).replace(
            dtype="float32", num_layers=2, d_model=64, d_ff=128,
            moe_d_ff=128, vocab_size=256, capacity_factor=1.25,
            scan_layers=False)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        prompt = np.arange(1, 14, dtype=np.int32)
        ref = _generate_sequential(model, params, prompt, 8)
        eng = ServeEngine(model, params, batch_size=4)
        res = eng.run([Request(uid=0, prompt=prompt, max_new_tokens=8)])
        np.testing.assert_array_equal(res[0].tokens, ref)

    def test_odp_protection_ignores_idle_slots(self):
        """ODP token protection (protect_ratio > 0) top-k's importance over
        the regrouped decode pool — idle-slot garbage must not steal
        protection quota from the live request."""
        cfg, model, params = _mixtral()
        mc = MCRuntime(odp=OdpRuntime(threshold=0.6, protect_ratio=0.25,
                                      capacity_scale=1.0))
        prompt = np.arange(1, 14, dtype=np.int32)
        ref = _generate_sequential(model, params, prompt, 10, mc=mc)
        eng = ServeEngine(model, params, batch_size=4, mc=mc)
        res = eng.run([Request(uid=0, prompt=prompt, max_new_tokens=10)])
        np.testing.assert_array_equal(res[0].tokens, ref)

    def test_deterministic_across_runs(self):
        cfg, model, params = _mixtral()
        reqs = _mixed_requests(cfg, 4, seed=3)
        eng = ServeEngine(model, params, batch_size=2)
        a = eng.run(reqs)
        b = eng.run(reqs)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x.tokens, y.tokens)


class TestSlotLifecycle:
    def test_admission_into_freed_slots(self):
        """5 requests through 2 slots: later requests must be admitted only
        as slots free up, and all must finish with their own lengths."""
        cfg, model, params = _dense()
        reqs = [Request(uid=i, prompt=np.arange(1, 6 + i, dtype=np.int32),
                        max_new_tokens=[2, 7, 3, 5, 4][i])
                for i in range(5)]
        eng = ServeEngine(model, params, batch_size=2)
        res = eng.run(reqs)
        assert sorted(r.uid for r in res) == [0, 1, 2, 3, 4]
        for req, r in zip(reqs, res):
            assert r.tokens.shape == (req.max_new_tokens,)
            assert r.finish_reason == "length"
        s = eng.stats
        # continuous overlap: fewer decode steps than the sum of the
        # per-request decode lengths (sequential), more than the longest one
        seq_steps = sum(r.max_new_tokens - 1 for r in reqs)
        assert s.decode_steps < seq_steps
        assert s.decode_steps >= max(r.max_new_tokens - 1 for r in reqs)

    def test_unequal_max_new_stats(self):
        """Stats accounting under unequal max_new_tokens: useful tokens are
        counted exactly; occupancy reflects tail-idle slots."""
        cfg, model, params = _dense()
        reqs = [Request(uid=0, prompt=np.arange(1, 9, dtype=np.int32),
                        max_new_tokens=12),
                Request(uid=1, prompt=np.arange(1, 5, dtype=np.int32),
                        max_new_tokens=2)]
        eng = ServeEngine(model, params, batch_size=2)
        eng.run(reqs)
        s = eng.stats
        assert s.requests == 2
        assert s.generated_tokens == 14
        assert s.slot_steps == s.decode_steps * 2
        assert 0 < s.active_slot_steps <= s.slot_steps
        # the short request leaves its slot idle for the long request's tail
        assert s.occupancy < 1.0
        assert s.decode_tokens_per_s > 0

    def test_duplicate_uids_keep_all_results(self):
        """Results are keyed by submission order, not uid — two requests
        sharing a uid must both come back, in order."""
        cfg, model, params = _dense()
        a = np.arange(1, 9, dtype=np.int32)
        b = np.arange(3, 15, dtype=np.int32)
        eng = ServeEngine(model, params, batch_size=2)
        res = eng.run([Request(uid=7, prompt=a, max_new_tokens=3),
                       Request(uid=7, prompt=b, max_new_tokens=4)])
        assert len(res) == 2
        np.testing.assert_array_equal(
            res[0].tokens, _generate_sequential(model, params, a, 3))
        np.testing.assert_array_equal(
            res[1].tokens, _generate_sequential(model, params, b, 4))

    def test_more_requests_than_slots_occupancy(self):
        """A saturated queue keeps freed slots busy: occupancy with a deep
        queue must beat the two-request tail-idle case."""
        cfg, model, params = _dense()
        deep = [Request(uid=i, prompt=np.arange(1, 7, dtype=np.int32),
                        max_new_tokens=4) for i in range(8)]
        eng = ServeEngine(model, params, batch_size=2)
        eng.run(deep)
        assert eng.stats.occupancy > 0.9


class TestStopping:
    def test_per_request_eos(self):
        """EOS must stop exactly the request that emits it, where the
        sequential reference emits it."""
        cfg, model, params = _dense()
        prompt = np.arange(1, 11, dtype=np.int32)
        ref = _generate_sequential(model, params, prompt, 8)
        eos = int(ref[3])
        first = int(np.nonzero(ref == eos)[0][0])
        reqs = [Request(uid=0, prompt=prompt, max_new_tokens=8, eos_id=eos),
                Request(uid=1, prompt=np.arange(1, 5, dtype=np.int32),
                        max_new_tokens=6)]
        eng = ServeEngine(model, params, batch_size=2)
        res = eng.run(reqs)
        np.testing.assert_array_equal(res[0].tokens, ref[:first + 1])
        assert res[0].finish_reason == "eos"
        assert res[1].tokens.shape == (6,)
        assert res[1].finish_reason == "length"

    def test_eos_frees_slot_for_pending(self):
        cfg, model, params = _dense()
        prompt = np.arange(1, 11, dtype=np.int32)
        ref = _generate_sequential(model, params, prompt, 8)
        eos = int(ref[1])
        reqs = [Request(uid=0, prompt=prompt, max_new_tokens=50,
                        eos_id=eos),
                Request(uid=1, prompt=np.arange(1, 7, dtype=np.int32),
                        max_new_tokens=5)]
        eng = ServeEngine(model, params, batch_size=1)
        res = eng.run(reqs)
        assert res[0].finish_reason == "eos"
        assert res[0].new_tokens < 50
        assert res[1].tokens.shape == (5,)


class TestStaticBaseline:
    def test_static_engine_still_serves(self):
        cfg, model, params = _mixtral()
        reqs = [Request(uid=i, prompt=np.arange(1, 8, dtype=np.int32),
                        max_new_tokens=4) for i in range(2)]
        eng = StaticServeEngine(model, params, batch_size=2)
        res = eng.run(reqs)
        assert all(r.tokens.shape == (4,) for r in res)
        res2 = eng.run(reqs)
        np.testing.assert_array_equal(res[0].tokens, res2[0].tokens)

    def test_static_eos_truncates(self):
        """The lockstep loop cannot retire an EOS'd request early, but the
        result must still be truncated at the EOS token."""
        cfg, model, params = _dense()
        prompt = np.arange(1, 11, dtype=np.int32)
        ref = _generate_sequential(model, params, prompt, 8)
        eos = int(ref[3])
        first = int(np.nonzero(ref == eos)[0][0])
        eng = StaticServeEngine(model, params, batch_size=1, eos_id=eos)
        res = eng.run([Request(uid=0, prompt=prompt, max_new_tokens=8)])
        np.testing.assert_array_equal(res[0].tokens, ref[:first + 1])
        assert res[0].finish_reason == "eos"
        assert eng.stats.generated_tokens == first + 1

    def test_static_equal_shape_batch_matches_continuous(self):
        """With identical prompt lengths (no left padding) the lockstep
        engine must produce the same tokens as the continuous engine."""
        cfg, model, params = _dense()
        reqs = [Request(uid=i,
                        prompt=(np.arange(1, 10, dtype=np.int32) + i),
                        max_new_tokens=5) for i in range(2)]
        stat = StaticServeEngine(model, params, batch_size=2).run(
            [Request(r.uid, r.prompt, r.max_new_tokens) for r in reqs])
        cont = ServeEngine(model, params, batch_size=2).run(reqs)
        for a, b in zip(stat, cont):
            np.testing.assert_array_equal(a.tokens, b.tokens)


class TestMeshBoot:
    """Engine boot plumbing around meshes: equal-mesh placement reuse
    (a rebuilt-but-equal mesh must not trigger a redundant place_params
    pass) and the ep_dispatch mesh-axis precondition order."""

    def _artifact_like(self, cfg, params, placed_mesh):
        import types
        return types.SimpleNamespace(
            model_fingerprint=cfg.fingerprint(), is_partial=False,
            params=params, placed_mesh=placed_mesh, runtime=None)

    def test_equal_mesh_skips_replacement(self, monkeypatch):
        import types
        from repro.core import pipeline as pl
        from repro.launch.mesh import single_device_mesh
        cfg, model, params = _dense()
        mesh = single_device_mesh()
        # an equal mesh that is NOT the same object (jax.make_mesh interns
        # equal meshes while its cache holds, so rebuild the device layout
        # by hand — exactly what a boot path reconstructing the mesh from
        # a config does)
        clone = types.SimpleNamespace(axis_names=mesh.axis_names,
                                      devices=mesh.devices.copy())
        assert clone is not mesh
        calls = []
        monkeypatch.setattr(pl, "place_params",
                            lambda p, m, **kw: (calls.append(1), p)[1])
        ServeEngine.from_artifact(
            model, self._artifact_like(cfg, params, clone), mesh=mesh,
            batch_size=2)
        assert calls == [], \
            "equal mesh must not re-place already-placed params"
        ServeEngine.from_artifact(
            model, self._artifact_like(cfg, params, None), mesh=mesh,
            batch_size=2)
        assert calls == [1], "unplaced artifact must be placed once"

    def test_meshes_equal_semantics(self):
        from repro.launch.mesh import single_device_mesh
        from repro.sharding.partitioning import meshes_equal
        a, b = single_device_mesh(), single_device_mesh()
        assert meshes_equal(a, a) and meshes_equal(a, b)
        other = jax.make_mesh((1, 1), ("x", "model"))
        assert not meshes_equal(a, other)
        assert not meshes_equal(a, None) and not meshes_equal(None, None)

    def test_ep_dispatch_without_data_axis_names_the_axis(self):
        """The mesh-axis check must run before the quant-meta class
        divisibility validator: with no 'data' axis the old order
        validated metas against a phantom axis of 1 and then raised a
        misleading batch-divisibility message."""
        from repro.models.layers.moe import MoEQuantMeta
        cfg, model, params = _dense()
        mesh = jax.make_mesh((1, 1), ("x", "model"))
        mc = MCRuntime(odp=None,
                       quant_meta=MoEQuantMeta(bit_classes=(1, 2),
                                               class_counts=(1, 3),
                                               group_size=32,
                                               pack_block=32),
                       layer_metas=None)
        with pytest.raises(ValueError, match="'data' axis"):
            ServeEngine(model, params, mesh=mesh, ep_dispatch=True,
                        mc=mc, batch_size=2)


class TestParseMesh:
    def test_rejects_nonpositive_dims(self):
        from repro.launch.serve import _parse_mesh
        for bad in ("0x2", "-1x4", "2x0"):
            with pytest.raises(SystemExit, match="positive"):
                _parse_mesh(bad)
        with pytest.raises(SystemExit, match="DxM"):
            _parse_mesh("abc")

    def test_accepts_valid_spec(self):
        from repro.launch.serve import _parse_mesh
        mesh = _parse_mesh("1x1")
        assert dict(mesh.shape) == {"data": 1, "model": 1}


# ----------------------------------------------------------------- paged KV
def _paged_family(name):
    """A paged-eligible smoke model of the given family (full, sliding,
    local/global or chunked attention; MoE gets a non-binding capacity)."""
    cfg = get_config(name, smoke=True).replace(
        dtype="float32", num_layers=2, vocab_size=256, scan_layers=False)
    if cfg.family == "moe":
        cfg = cfg.replace(capacity_factor=8.0)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _kv_engine(model, params, batch=3, max_seq_len=32, **pool_kw):
    from repro.serve.engine import EngineConfig
    from repro.serve.kv_pool import KVPoolConfig
    pool = KVPoolConfig(**pool_kw) if pool_kw else None
    return ServeEngine(model, params, config=EngineConfig(
        batch_size=batch, max_seq_len=max_seq_len, kv_pool=pool))


def _tokens_by_uid(results):
    return {r.uid: r.tokens for r in results}


class TestPagedKV:
    """The paged memory layer preserves every token-identity contract."""

    @pytest.mark.parametrize(
        "name",
        [pytest.param("internlm2-1.8b", id="dense"),
         pytest.param("mixtral-8x7b", id="moe", marks=pytest.mark.slow),
         pytest.param("h2o-danube-3-4b", id="sliding",
                      marks=pytest.mark.slow),
         pytest.param("gemma2-27b", id="local_global",
                      marks=pytest.mark.slow),
         pytest.param("llama4-maverick-400b-a17b", id="chunked_moe",
                      marks=pytest.mark.slow)])
    def test_paged_matches_contiguous(self, name):
        """Paged pool, quantization off: bit-exact against the contiguous
        engine across every paged-eligible attention family."""
        cfg, model, params = _paged_family(name)
        reqs = _mixed_requests(cfg, 5, seed=3)
        ref = _tokens_by_uid(
            _kv_engine(model, params).run(_mixed_requests(cfg, 5, seed=3)))
        out = _tokens_by_uid(
            _kv_engine(model, params, num_pages=32, page_size=4).run(reqs))
        assert set(ref) == set(out)
        for uid in ref:
            assert np.array_equal(ref[uid], out[uid]), (
                f"request {uid}: paged {out[uid]} != contiguous {ref[uid]}")

    @pytest.mark.parametrize("quant", ["int8", "int4"])
    def test_quantized_first_token_exact(self, quant):
        """Prefill runs full-precision and quantizes only on the page
        scatter, so the first generated token is exact at any setting."""
        cfg, model, params = _dense()
        reqs = _mixed_requests(cfg, 4, seed=5)
        ref = _tokens_by_uid(
            _kv_engine(model, params).run(_mixed_requests(cfg, 4, seed=5)))
        out = _tokens_by_uid(_kv_engine(model, params, num_pages=32,
                                        page_size=4, quant=quant).run(reqs))
        for uid in ref:
            assert ref[uid][0] == out[uid][0], f"request {uid} first token"

    def test_int8_decode_matches_reference(self):
        """int8 KV decode on the smoke model stays within the pinned
        serving tolerance; greedy argmax is far inside it, so tokens
        match the bf16 contiguous engine outright (the logits-level bound
        itself is pinned by tests/test_kv_quant.py on captured KV)."""
        cfg, model, params = _dense()
        reqs = _mixed_requests(cfg, 5, seed=7)
        ref = _tokens_by_uid(
            _kv_engine(model, params).run(_mixed_requests(cfg, 5, seed=7)))
        out = _tokens_by_uid(_kv_engine(model, params, num_pages=32,
                                        page_size=4, quant="int8").run(reqs))
        for uid in ref:
            assert np.array_equal(ref[uid], out[uid])

    def test_prefix_shared_decodes_identically(self):
        """Requests sharing a system-prompt prefix decode exactly as
        without sharing, and sharing actually happens (refcounted pages,
        not copies)."""
        cfg, model, params = _dense()
        sys_prompt = np.arange(1, 13, dtype=np.int32)       # 3 full pages

        def reqs():
            return [Request(uid=i,
                            prompt=np.concatenate(
                                [sys_prompt, [50 + i, 60 + i]]
                            ).astype(np.int32),
                            max_new_tokens=6) for i in range(4)]

        unshared = _kv_engine(model, params, num_pages=64, page_size=4,
                              prefix_sharing=False)
        ref = _tokens_by_uid(unshared.run(reqs()))
        assert unshared._kv_mgr.stats.shared_pages == 0
        shared = _kv_engine(model, params, num_pages=64, page_size=4,
                            prefix_sharing=True)
        out = _tokens_by_uid(shared.run(reqs()))
        assert shared._kv_mgr.stats.shared_pages > 0
        for uid in ref:
            assert np.array_equal(ref[uid], out[uid])
        # the prefix cache persists across sessions: a second run shares
        # from the very first admission and still decodes identically
        before = shared._kv_mgr.stats.shared_pages
        again = _tokens_by_uid(shared.run(reqs()))
        assert shared._kv_mgr.stats.shared_pages > before
        for uid in ref:
            assert np.array_equal(ref[uid], again[uid])
        shared._kv_mgr.check_invariants()

    def test_chunked_prefill_identical(self):
        """Chunked prefill (chunks interleaved with decode) changes
        scheduling, never tokens."""
        cfg, model, params = _dense()
        reqs = _mixed_requests(cfg, 5, seed=11)
        ref = _tokens_by_uid(
            _kv_engine(model, params, num_pages=32, page_size=4)
            .run(_mixed_requests(cfg, 5, seed=11)))
        out = _tokens_by_uid(
            _kv_engine(model, params, num_pages=32, page_size=4,
                       prefill_chunk=4).run(reqs))
        for uid in ref:
            assert np.array_equal(ref[uid], out[uid])

    def test_queue_until_pages_free(self):
        """A pool too small for the full workload serves it anyway by
        queueing admissions until earlier requests release pages — the
        old mid-pump capacity error is gone."""
        cfg, model, params = _dense()

        def reqs():
            return [Request(uid=i, prompt=np.arange(1, 9, dtype=np.int32),
                            max_new_tokens=8) for i in range(4)]

        ref = _tokens_by_uid(_kv_engine(model, params, batch=2,
                                        max_seq_len=16).run(reqs()))
        eng = _kv_engine(model, params, batch=2, max_seq_len=16,
                         num_pages=6, page_size=4, prefix_sharing=False)
        out = _tokens_by_uid(eng.run(reqs()))
        assert eng._kv_mgr.stats.failed_admits > 0
        for uid in ref:
            assert np.array_equal(ref[uid], out[uid])
        eng._kv_mgr.check_invariants()
        assert eng._kv_mgr.num_free == eng._kv_mgr.usable_pages

    def test_single_request_exceeding_pool_raises(self):
        """queue-until-free never hides the impossible case: one request
        larger than the whole pool is a loud error at submission."""
        cfg, model, params = _dense()
        eng = _kv_engine(model, params, batch=2, max_seq_len=32,
                         num_pages=4, page_size=4)       # 12 usable tokens
        req = Request(uid=0, prompt=np.arange(1, 17, dtype=np.int32),
                      max_new_tokens=8)
        with pytest.raises(ValueError, match="whole pool"):
            eng.run([req])
        ok = Request(uid=1, prompt=np.arange(1, 5, dtype=np.int32),
                     max_new_tokens=4)
        eng.begin([ok])
        with pytest.raises(ValueError, match="whole pool"):
            eng.submit([req])
        while eng.busy:
            eng.pump()
        assert len(eng.collect()) == 1

    def test_no_retrace_across_page_counts(self):
        """The page table is a jit input: requests occupying different
        page counts (and on-demand growth) share ONE compiled decode."""
        cfg, model, params = _dense()
        eng = _kv_engine(model, params, num_pages=32, page_size=4)
        reqs = [Request(uid=i,
                        prompt=np.arange(1, 3 + 7 * i, dtype=np.int32),
                        max_new_tokens=3 + 4 * i) for i in range(3)]
        eng.run(reqs)
        assert eng._decode_paged._cache_size() == 1

    def test_paged_rejects_by_capability(self):
        """Paging eligibility is decided per state kind, not per family:
        only a family with NO pageable kind (pure SSM) rejects a pool, and
        the error names the state kinds; recurrent + chunked prefill is the
        one genuinely unsupported combination."""
        from repro.serve.engine import EngineConfig
        from repro.serve.kv_pool import KVPoolConfig
        pool = KVPoolConfig(num_pages=8, page_size=4)
        mamba = build_model(get_config("falcon-mamba-7b", smoke=True))
        with pytest.raises(ValueError, match="no-op.*ssm"):
            ServeEngine(mamba, None, config=EngineConfig(
                max_seq_len=32, kv_pool=pool))
        # hybrids page their shared-attention kind but cannot chunk the
        # prefill through the recurrence
        hyb = build_model(get_config("zamba2-1.2b", smoke=True))
        with pytest.raises(ValueError, match="recurrent"):
            ServeEngine(hyb, None, config=EngineConfig(
                max_seq_len=32,
                kv_pool=KVPoolConfig(num_pages=8, page_size=4,
                                     prefill_chunk=4)))
        cfg, model, params = _dense()
        with pytest.raises(ValueError, match="max_seq_len"):
            ServeEngine(model, params,
                        config=EngineConfig(kv_pool=pool))
        with pytest.raises(ValueError, match="continuous"):
            StaticServeEngine(model, params, config=EngineConfig(
                max_seq_len=32, kv_pool=pool))
        quant_cfg = cfg.replace(kv_quant=True)
        qmodel = build_model(quant_cfg)
        with pytest.raises(ValueError, match="kv_quant"):
            ServeEngine(qmodel, None, config=EngineConfig(
                max_seq_len=32, kv_pool=pool))

    def test_drain_releases_pages(self):
        """Draining a paged session releases every in-flight allocation;
        the requeued continuations decode token-identically."""
        cfg, model, params = _dense()
        reqs = _mixed_requests(cfg, 4, seed=13)
        ref = _tokens_by_uid(
            _kv_engine(model, params, num_pages=32, page_size=4)
            .run(_mixed_requests(cfg, 4, seed=13)))
        eng = _kv_engine(model, params, num_pages=32, page_size=4)
        eng.begin(reqs)
        eng.pump()
        eng.pump()
        requeued = eng.drain()
        eng.collect()
        eng._kv_mgr.check_invariants()
        done = _tokens_by_uid(eng.run([r.continuation() for r in requeued]))
        stitched = {r.request.uid:
                    np.concatenate([r.prior_tokens, done[r.request.uid]])
                    for r in requeued}
        for uid, toks in stitched.items():
            assert np.array_equal(ref[uid], toks.astype(np.int32)), uid


def _family_model(name):
    """A smoke model served through the per-slot state layer. float32 keeps
    greedy argmax deterministic across batch compositions."""
    cfg = get_config(name, smoke=True).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _family_requests(cfg, n, max_new=5, seed=0):
    """Mixed-length requests with the encoder-side input the family needs
    (encoder frames for encdec, prefix embeddings for vlm)."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        prompt = rng.integers(1, cfg.vocab_size,
                              int(rng.integers(3, 9))).astype(np.int32)
        enc = None
        if cfg.family == "encdec":
            enc = rng.standard_normal(
                (cfg.encoder_seq, cfg.d_model)).astype(np.float32)
        elif cfg.family == "vlm":
            enc = rng.standard_normal(
                (cfg.num_prefix_tokens, cfg.d_model)).astype(np.float32)
        reqs.append(Request(uid=i, prompt=prompt, enc_input=enc,
                            max_new_tokens=max_new))
    return reqs


def _family_sequential(model, params, req):
    """Greedy one-request reference through the model's own prefill/decode
    steps (no engine), carrying the family's encoder-side input."""
    cfg = model.cfg
    prompt, max_new = req.prompt, req.max_new_tokens
    plen = cfg.num_prefix_tokens if cfg.family == "vlm" else 0
    caches = model.init_caches(1, plen + len(prompt) + max_new)
    kw, dkw = {}, {}
    if cfg.family == "encdec":
        frames = jnp.asarray(req.enc_input, jnp.float32)[None]
        kw["enc_frames"] = frames
        dkw["cross"] = model.cross_kv(params, model.encode(params, frames))
    elif cfg.family == "vlm":
        kw["prefix_embeds"] = jnp.asarray(req.enc_input, jnp.float32)[None]
    logits, caches, _ = model.forward(
        params, jnp.asarray(prompt, jnp.int32)[None], caches=caches, **kw)
    out = [int(jnp.argmax(logits[0, -1]))]
    pos = plen + len(prompt)
    for _ in range(max_new - 1):
        logits, caches = model.decode_step(
            params, caches, jnp.asarray([[out[-1]]], jnp.int32),
            jnp.asarray(pos, jnp.int32), **dkw)
        out.append(int(jnp.argmax(logits[0, -1])))
        pos += 1
    return np.asarray(out, np.int32)


class TestStateLayer:
    """The family-agnostic per-slot state layer: every family's state
    kinds ride the same admit/insert/decode/drain machinery."""

    @pytest.mark.parametrize(
        "name", [pytest.param("zamba2-1.2b", id="hybrid"),
                 pytest.param("whisper-medium", id="encdec"),
                 pytest.param("paligemma-3b", id="vlm"),
                 pytest.param("falcon-mamba-7b", id="ssm")])
    def test_engine_matches_sequential(self, name):
        """Continuous batching over the dense slot pools is token-identical
        to one-request-at-a-time generation for every state-kind mix."""
        cfg, model, params = _family_model(name)
        reqs = _family_requests(cfg, 3, seed=2)
        eng = _kv_engine(model, params, batch=2, max_seq_len=48)
        res = eng.run(reqs)
        for req, r in zip(reqs, res):
            ref = _family_sequential(model, params, req)
            np.testing.assert_array_equal(r.tokens, ref,
                                          err_msg=f"uid {req.uid}")
        assert eng.stats.scratch_reuses == 2    # 3 admissions, 1 alloc

    @pytest.mark.parametrize(
        "name", [pytest.param("zamba2-1.2b", id="hybrid"),
                 pytest.param("whisper-medium", id="encdec"),
                 pytest.param("paligemma-3b", id="vlm")])
    def test_paged_matches_contiguous(self, name):
        """Paging the pageable state kinds (hybrid shared-attention KV,
        encdec decoder self-attention KV, vlm prefix+prompt KV) preserves
        greedy tokens exactly; mixed page counts share one compiled step."""
        cfg, model, params = _family_model(name)
        ref = _tokens_by_uid(
            _kv_engine(model, params, batch=2, max_seq_len=48)
            .run(_family_requests(cfg, 4, seed=4)))
        eng = _kv_engine(model, params, batch=2, max_seq_len=48,
                         num_pages=64, page_size=8)
        out = _tokens_by_uid(eng.run(_family_requests(cfg, 4, seed=4)))
        for uid in ref:
            assert np.array_equal(ref[uid], out[uid]), (
                f"request {uid}: paged {out[uid]} != contiguous {ref[uid]}")
        assert eng._decode_paged._cache_size() == 1
        eng._kv_mgr.check_invariants()

    def test_cross_kv_shared_across_identical_encoder_inputs(self):
        """Requests with the same encoder input share ONE refcounted
        CrossKV pool entry (refcount > 1 while both are in flight), and
        distinct inputs do not alias."""
        cfg, model, params = _family_model("whisper-medium")
        reqs = _family_requests(cfg, 3, seed=6)
        reqs[1] = Request(uid=1, prompt=reqs[1].prompt,
                          enc_input=reqs[0].enc_input,
                          max_new_tokens=reqs[1].max_new_tokens)
        eng = _kv_engine(model, params, batch=3, max_seq_len=48)
        from repro.serve.kv_pool import SharedStatePool
        key01 = SharedStatePool.key_of(
            np.asarray(reqs[0].enc_input, np.float32))
        key2 = SharedStatePool.key_of(
            np.asarray(reqs[2].enc_input, np.float32))
        eng.begin(reqs)
        eng.pump()                       # all three admitted (3 slots)
        assert eng._shared_pool.refcount(key01) == 2
        assert eng._shared_pool.refcount(key2) == 1
        assert eng._shared_pool.stats.hits == 1
        assert eng._shared_pool.stats.misses == 2
        while eng.busy:
            eng.pump()
        eng.collect()
        # exactly zero at release: every acquire had its release
        assert eng._shared_pool.refcount(key01) == 0
        assert eng._shared_pool.refcount(key2) == 0

    def test_missing_enc_input_is_friendly(self):
        """Submitting an encdec request without encoder input (or a vlm
        request with the wrong prefix shape) fails with a message naming
        the expected shape, not a jit shape error."""
        cfg, model, params = _family_model("whisper-medium")
        eng = _kv_engine(model, params, batch=2, max_seq_len=48)
        with pytest.raises(ValueError, match="enc_input"):
            eng.run([Request(uid=0, prompt=np.arange(1, 5, dtype=np.int32),
                             max_new_tokens=2)])

    def test_static_engine_rejects_shared_state(self):
        """The lockstep baseline carries no per-request encoder input; the
        error says to use the continuous engine."""
        cfg, model, params = _family_model("whisper-medium")
        from repro.serve.engine import EngineConfig
        with pytest.raises(ValueError, match="continuous ServeEngine"):
            StaticServeEngine(model, params,
                              config=EngineConfig(max_seq_len=32))
