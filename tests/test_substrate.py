"""Tests: checkpointing, fault tolerance, elasticity, data pipeline,
optimizer (incl. 8-bit states), gradient compression, serve engine.
"""
import threading
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import (CheckpointManager, latest_step,
                                           restore_pytree, save_pytree)
from repro.config import MeshConfig, TrainConfig
from repro.configs import get_config
from repro.data.pipeline import (Prefetcher, SyntheticTextConfig,
                                 SyntheticTokenDataset, calibration_batch)
from repro.models.model_registry import build_model
from repro.runtime.elastic import plan_elastic, validate_resharding
from repro.runtime.fault_tolerance import (Heartbeat, StragglerDetector,
                                           run_with_fault_tolerance)
from repro.serve.engine import Request, ServeEngine
from repro.train import optimizer as opt_lib
from repro.train.grad_compression import compress_decompress_ef
from repro.train.train_step import init_train_state, make_train_step


# -------------------------------------------------------------- checkpoint
class TestCheckpoint:
    def _tree(self, key=0):
        k = jax.random.PRNGKey(key)
        return {"a": jax.random.normal(k, (32, 16)),
                "nested": {"b": jnp.arange(10, dtype=jnp.int32),
                           "c": (jnp.ones((4,)), jnp.zeros((2, 2)))}}

    def test_roundtrip(self, tmp_path):
        tree = self._tree()
        save_pytree(tmp_path, 7, tree, meta={"cfg": "x"})
        out, step = restore_pytree(tmp_path, jax.eval_shape(lambda: tree))
        assert step == 7
        for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_latest_pointer_and_rotation(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=2, async_save=False)
        for s in (1, 2, 3, 4):
            mgr.save(s, self._tree(s))
        assert mgr.latest_step() == 4
        kept = sorted(p.name for p in tmp_path.glob("step_*"))
        assert len(kept) == 2

    def test_atomicity_partial_write_ignored(self, tmp_path):
        save_pytree(tmp_path, 1, self._tree())
        # simulate a crashed writer: orphan tmp dir
        (tmp_path / "step_00000002.tmp-999").mkdir()
        assert latest_step(tmp_path) == 1

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=3, async_save=True)
        mgr.save(5, self._tree())
        mgr.wait()
        assert mgr.latest_step() == 5

    def test_shape_mismatch_rejected(self, tmp_path):
        save_pytree(tmp_path, 1, {"a": jnp.ones((4,))})
        with pytest.raises(ValueError):
            restore_pytree(tmp_path, {"a": jnp.ones((5,))})


# -------------------------------------------------------- fault tolerance
class TestFaultTolerance:
    def test_crash_restart_resumes_exactly(self, tmp_path):
        """Inject a crash mid-run; final state must equal a crash-free run."""
        def make_state():
            return {"x": jnp.zeros(()), "hist": jnp.zeros((20,))}

        def step_fn(state, step):
            return {"x": state["x"] + step,
                    "hist": state["hist"].at[step].set(step)}

        crashed = {"done": False}

        def injector(step):
            if step == 13 and not crashed["done"]:
                crashed["done"] = True
                raise RuntimeError("synthetic preemption")

        mgr = CheckpointManager(tmp_path / "ft", keep=3, async_save=False)
        report = run_with_fault_tolerance(
            total_steps=20, make_state=make_state, step_fn=step_fn,
            ckpt_manager=mgr, checkpoint_every=5, fail_injector=injector)
        assert report.restarts == 1
        assert report.completed_steps == 20
        final, _ = mgr.restore(jax.eval_shape(make_state))
        expected = sum(range(20))
        assert float(final["x"]) == expected
        np.testing.assert_array_equal(np.asarray(final["hist"]),
                                      np.arange(20, dtype=np.float32))

    def test_max_restarts_exceeded(self, tmp_path):
        mgr = CheckpointManager(tmp_path / "ft2", keep=2, async_save=False)

        def injector(step):
            raise RuntimeError("persistent failure")

        with pytest.raises(RuntimeError):
            run_with_fault_tolerance(
                total_steps=5, make_state=lambda: {"x": jnp.zeros(())},
                step_fn=lambda s, i: s, ckpt_manager=mgr,
                checkpoint_every=2, max_restarts=2, fail_injector=injector)

    def test_straggler_detector(self):
        det = StragglerDetector(z_threshold=3.0, warmup=5)
        for i in range(30):
            det.observe(i, 0.1 + 0.001 * (i % 3))
        assert not det.flagged
        assert det.observe(31, 1.5)  # 15x step time
        assert det.flagged[-1]["step"] == 31

    def test_heartbeat_dead_worker(self, tmp_path):
        hb = Heartbeat(tmp_path / "hb", worker_id=3)
        hb.beat(step=10)
        assert Heartbeat.dead_workers(tmp_path / "hb", timeout_s=100) == []
        assert Heartbeat.dead_workers(tmp_path / "hb", timeout_s=0.0,
                                      now=time.time() + 10) == [3]

    def test_heartbeat_skips_corrupt_files(self, tmp_path):
        """A torn/corrupt heartbeat file must never take the detector
        down — it is skipped, healthy workers still report."""
        d = tmp_path / "hb"
        Heartbeat(d, worker_id=1).beat(step=5, now=0.0)
        Heartbeat(d, worker_id=2).beat(step=5, now=100.0)
        (d / "hb_7.json").write_text('{"worker": 7, "time"')   # torn write
        (d / "hb_8.json").write_text('{"step": 1, "time": 0}')  # no worker
        (d / "hb_9.json").write_text('{"worker": "x", "time": 0}')
        recs = Heartbeat.read_all(d)
        assert sorted(recs) == [1, 2]
        assert Heartbeat.dead_workers(d, timeout_s=10, now=100.0) == [1]

    def test_heartbeat_logical_clock_and_retire(self, tmp_path):
        d = tmp_path / "hb"
        hb = Heartbeat(d, worker_id=0)
        hb.beat(step=1, now=5.0)
        assert Heartbeat.read_all(d)[0]["time"] == 5.0
        assert Heartbeat.dead_workers(d, timeout_s=3, now=9.0) == [0]
        hb.retire()
        assert Heartbeat.read_all(d) == {}
        hb.retire()                       # idempotent

    def test_restart_accounting(self, tmp_path):
        """``restarts`` counts only *completed* restarts; the run that
        exhausts the budget records a fatal failure string instead."""
        mgr = CheckpointManager(tmp_path / "ft3", keep=2, async_save=False)
        fails = {"n": 0}

        def injector(step):
            if fails["n"] < 2:
                fails["n"] += 1
                raise RuntimeError(f"crash {fails['n']}")

        report = run_with_fault_tolerance(
            total_steps=4, make_state=lambda: {"x": jnp.zeros(())},
            step_fn=lambda s, i: s, ckpt_manager=mgr, checkpoint_every=2,
            max_restarts=3, fail_injector=injector)
        assert report.restarts == 2
        assert len(report.failures) == 2
        assert "@ restart 2" in report.failures[-1]

    def test_restart_accounting_fatal(self, tmp_path):
        """The fatal (budget-exhausting) failure is recorded but NOT
        counted as a restart — none happens."""
        mgr = CheckpointManager(tmp_path / "ft4", keep=2, async_save=False)

        def injector(step):
            raise RuntimeError("persistent failure")

        with pytest.raises(RuntimeError) as ei:
            run_with_fault_tolerance(
                total_steps=5, make_state=lambda: {"x": jnp.zeros(())},
                step_fn=lambda s, i: s, ckpt_manager=mgr,
                checkpoint_every=2, max_restarts=2, fail_injector=injector)
        report = ei.value.ft_report
        assert report.restarts == 2            # 2 tolerated, 3rd fatal
        assert len(report.failures) == 3
        assert "fatal" in report.failures[-1]
        assert "persistent failure" in report.failures[-1]


# ---------------------------------------------------------------- elastic
class TestElastic:
    def test_downsize_preserves_model_axis(self):
        mesh = MeshConfig(shape=(16, 16), axis_names=("data", "model"))
        plan = plan_elastic(mesh, surviving_devices=192, global_batch=256)
        assert plan.new_mesh.axis_size("model") == 16
        assert plan.new_mesh.axis_size("data") == 8
        assert plan.grad_accum == 2
        assert plan.new_global_batch % 8 == 0

    def test_multipod(self):
        mesh = MeshConfig(shape=(2, 16, 16),
                          axis_names=("pod", "data", "model"))
        plan = plan_elastic(mesh, surviving_devices=384, global_batch=256)
        assert plan.new_mesh.multi_pod
        assert plan.new_mesh.axis_size("model") == 16

    def test_too_few_devices_raises(self):
        mesh = MeshConfig(shape=(16, 16), axis_names=("data", "model"))
        with pytest.raises(ValueError):
            plan_elastic(mesh, surviving_devices=8, global_batch=256)

    def test_validate_resharding(self):
        mesh = MeshConfig(shape=(16, 16), axis_names=("data", "model"))
        issues = validate_resharding(
            {"w": (2048, 8192), "odd": (7, 9)}, mesh)
        assert "w" not in issues
        assert "odd" in issues

    def test_survivors_below_model_axis_raises(self):
        """Any survivor count under the model axis is unservable — the TP
        tile shapes cannot be preserved."""
        mesh = MeshConfig(shape=(4, 16), axis_names=("data", "model"))
        for n in (15, 8, 1):
            with pytest.raises(ValueError):
                plan_elastic(mesh, surviving_devices=n, global_batch=64)

    def test_single_device_survivor(self):
        """A 1x1 mesh down to one device: a degenerate but valid plan."""
        mesh = MeshConfig(shape=(4, 1), axis_names=("data", "model"))
        plan = plan_elastic(mesh, surviving_devices=1, global_batch=16)
        assert plan.new_mesh.axis_size("data") == 1
        assert plan.new_mesh.axis_size("model") == 1
        assert plan.grad_accum == 4
        assert plan.new_global_batch >= 1

    def test_non_power_of_two_survivors(self):
        """Odd survivor counts round the data axis down to a power of
        two; leftover devices idle rather than break collectives."""
        mesh = MeshConfig(shape=(16, 4), axis_names=("data", "model"))
        plan = plan_elastic(mesh, surviving_devices=23, global_batch=256)
        # 23 // 4 = 5 data-parallel candidates -> largest pow2 is 4
        assert plan.new_mesh.axis_size("data") == 4
        assert plan.new_mesh.axis_size("model") == 4
        assert plan.grad_accum == 4
        assert plan.new_global_batch % 4 == 0

    def test_exact_model_axis_survivor(self):
        """Exactly the model axis left: data collapses to 1."""
        mesh = MeshConfig(shape=(8, 8), axis_names=("data", "model"))
        plan = plan_elastic(mesh, surviving_devices=8, global_batch=64)
        assert plan.new_mesh.axis_size("data") == 1
        assert plan.new_mesh.axis_size("model") == 8
        assert plan.grad_accum == 8

    def test_validate_resharding_edges(self):
        mesh = MeshConfig(shape=(1, 1), axis_names=("data", "model"))
        # everything divides a 1x1 mesh
        assert validate_resharding({"w": (7, 9), "v": (3,)}, mesh) == {}
        mesh = MeshConfig(shape=(2, 8), axis_names=("data", "model"))
        issues = validate_resharding(
            {"ok": (4, 16), "vec": (16,), "last_dim_1": (6, 1),
             "bad": (5, 12)}, mesh)
        assert "ok" not in issues and "vec" not in issues
        assert "last_dim_1" not in issues     # dim 1 never shards
        assert "bad" in issues


# ------------------------------------------------------------------- data
class TestData:
    def _cfg(self, **kw):
        d = dict(vocab_size=1000, seq_len=32, global_batch=8, seed=0)
        d.update(kw)
        return SyntheticTextConfig(**d)

    def test_step_determinism(self):
        ds = SyntheticTokenDataset(self._cfg())
        b1, b2 = ds.batch(17), ds.batch(17)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        b3 = ds.batch(18)
        assert not np.array_equal(b1["tokens"], b3["tokens"])

    def test_labels_are_shifted_tokens(self):
        ds = SyntheticTokenDataset(self._cfg())
        b = ds.batch(0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_host_sharding(self):
        full = SyntheticTokenDataset(self._cfg(num_hosts=1)).batch(3)
        h0 = SyntheticTokenDataset(self._cfg(num_hosts=2, host_id=0)).batch(3)
        assert h0["tokens"].shape[0] == 4
        assert full["tokens"].shape[0] == 8

    def test_tokens_in_range(self):
        b = SyntheticTokenDataset(self._cfg()).batch(0)
        assert b["tokens"].min() >= 0 and b["tokens"].max() < 1000

    def test_prefetcher(self):
        ds = SyntheticTokenDataset(self._cfg())
        pf = Prefetcher(ds, start_step=5, depth=2)
        s, b = pf.next()
        assert s == 5
        s2, _ = pf.next()
        assert s2 == 6
        pf.stop()

    def test_calibration_batch(self):
        cfg = get_config("mixtral-8x7b", smoke=True)
        toks = calibration_batch(cfg, 4, 64)
        assert toks.shape == (4, 64)


# -------------------------------------------------------------- optimizer
class TestOptimizer:
    def _setup(self, opt="adamw"):
        tcfg = TrainConfig(optimizer=opt, learning_rate=0.1,
                           warmup_steps=0, total_steps=100, weight_decay=0.0)
        params = {"w": jnp.ones((8, 64)), "b": jnp.zeros((64,))}
        state = opt_lib.adamw_init(params, tcfg)
        return tcfg, params, state

    def test_adamw_descends(self):
        tcfg, params, state = self._setup()
        grads = {"w": jnp.ones((8, 64)), "b": jnp.ones((64,))}
        new_p, state = opt_lib.adamw_update(grads, state, params,
                                            jnp.asarray(0.1), tcfg)
        assert float(new_p["w"].mean()) < 1.0
        assert int(state.step) == 1

    def test_8bit_moments_are_int8(self):
        tcfg, params, state = self._setup("adamw8bit")
        assert state.m["w"].q.dtype == jnp.int8
        # small vectors stay dense f32
        assert state.m["b"].dtype == jnp.float32 \
            if not hasattr(state.m["b"], "q") else True

    def test_8bit_tracks_fp32(self):
        """Quantized-state AdamW stays close to exact AdamW over steps."""
        tcfg_f, params, s_f = self._setup("adamw")
        tcfg_q, _, s_q = self._setup("adamw8bit")
        p_f = p_q = params
        key = jax.random.PRNGKey(0)
        for i in range(20):
            key, k = jax.random.split(key)
            g = {"w": jax.random.normal(k, (8, 64)),
                 "b": jax.random.normal(k, (64,))}
            p_f, s_f = opt_lib.adamw_update(g, s_f, p_f, jnp.asarray(0.01),
                                            tcfg_f)
            p_q, s_q = opt_lib.adamw_update(g, s_q, p_q, jnp.asarray(0.01),
                                            tcfg_q)
        diff = float(jnp.abs(p_f["w"] - p_q["w"]).max())
        scale = float(jnp.abs(p_f["w"]).max())
        assert diff / scale < 0.05, diff

    def test_grad_clip(self):
        tree = {"a": jnp.full((10,), 100.0)}
        clipped, norm = opt_lib.clip_by_global_norm(tree, 1.0)
        assert float(opt_lib.global_norm(clipped)) <= 1.0 + 1e-5
        assert float(norm) > 100

    def test_q8_roundtrip(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (16, 256))
        t = opt_lib.q8_encode(x)
        err = jnp.abs(opt_lib.q8_decode(t) - x).max()
        assert float(err) <= float(jnp.abs(x).max()) / 127 + 1e-6


class TestGradCompression:
    def test_error_feedback_reduces_bias(self):
        """With EF, the accumulated compressed sum converges to the true
        sum (the 1-bit-Adam guarantee); without EF it drifts."""
        key = jax.random.PRNGKey(0)
        g_total = jnp.zeros((4, 64))
        acc_ef = jnp.zeros((4, 64))
        ef = {"g": jnp.zeros((4, 64))}
        acc_no = jnp.zeros((4, 64))
        for i in range(50):
            key, k = jax.random.split(key)
            g = jax.random.normal(k, (4, 64)) * (1 + 10 * (i % 7 == 0))
            g_total += g
            out, ef_new = compress_decompress_ef({"g": g}, ef)
            ef = ef_new
            acc_ef += out["g"]
            from repro.train.grad_compression import _q8_roundtrip
            acc_no += _q8_roundtrip(g)
        err_ef = float(jnp.abs(acc_ef + ef["g"] - g_total).max())
        err_no = float(jnp.abs(acc_no - g_total).max())
        assert err_ef < err_no
        assert err_ef < 1e-3


# ---------------------------------------------------------------- serving
class TestServeEngine:
    def test_generation_runs_and_stats(self):
        cfg = get_config("internlm2-1.8b", smoke=True)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        eng = ServeEngine(model, params, batch_size=2)
        reqs = [Request(uid=i, prompt=np.arange(1, 6 + i, dtype=np.int32),
                        max_new_tokens=4) for i in range(3)]
        results = eng.run(reqs)
        assert len(results) == 3
        for r in results:
            assert r.tokens.shape == (4,)
            assert (r.tokens >= 0).all()
        assert eng.stats.generated_tokens == 12
        assert eng.stats.decode_tokens_per_s > 0

    def test_greedy_deterministic(self):
        cfg = get_config("internlm2-1.8b", smoke=True)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        eng = ServeEngine(model, params, batch_size=1)
        r1 = eng.run([Request(0, np.arange(1, 9, dtype=np.int32), 6)])
        r2 = eng.run([Request(0, np.arange(1, 9, dtype=np.int32), 6)])
        np.testing.assert_array_equal(r1[0].tokens, r2[0].tokens)


# --------------------------------------------------- end-to-end train step
class TestTrainStepIntegration:
    def test_loss_decreases_small_model(self):
        cfg = get_config("internlm2-1.8b", smoke=True).replace(
            num_layers=2, scan_layers=False)
        model = build_model(cfg)
        tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=2,
                           total_steps=30, optimizer="adamw8bit")
        state = init_train_state(model, jax.random.PRNGKey(0), tcfg)
        step = jax.jit(make_train_step(model, cfg, tcfg))
        ds = SyntheticTokenDataset(SyntheticTextConfig(
            vocab_size=cfg.vocab_size, seq_len=32, global_batch=8, seed=0))
        losses = []
        for i in range(12):
            b = ds.batch(0)  # overfit one batch
            state, metrics = step(state, {k: jnp.asarray(v)
                                          for k, v in b.items()})
            losses.append(float(metrics["ce_loss"]))
        assert losses[-1] < losses[0] - 0.5, losses
