"""End-to-end behaviour tests for the paper's system: the full MC pipeline
(train -> calibrate -> PMQ quantize -> ODP -> serve) through the public API.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.config import CompressionConfig, TrainConfig
from repro.configs import get_config
from repro.core import pipeline
from repro.data.pipeline import (SyntheticTextConfig, SyntheticTokenDataset,
                                 calibration_batch)
from repro.models.model_registry import build_model
from repro.serve.engine import Request, ServeEngine
from repro.train.train_step import init_train_state, make_train_step
import pytest

pytestmark = pytest.mark.slow



def test_full_mc_lifecycle():
    """Train a small MoE, compress it with MC, serve it — the paper's
    deployment story end to end."""
    cfg = get_config("mixtral-8x7b", smoke=True).replace(
        dtype="float32", num_layers=2, d_model=64, d_ff=128, moe_d_ff=128,
        vocab_size=256, capacity_factor=4.0, scan_layers=False)
    model = build_model(cfg)

    # 1. brief training so the router specializes
    tcfg = TrainConfig(learning_rate=2e-3, warmup_steps=2, total_steps=20,
                       optimizer="adamw8bit")
    state = init_train_state(model, jax.random.PRNGKey(0), tcfg)
    step = jax.jit(make_train_step(model, cfg, tcfg))
    ds = SyntheticTokenDataset(SyntheticTextConfig(
        vocab_size=cfg.vocab_size, seq_len=32, global_batch=4, seed=0))
    first = last = None
    for i in range(20):
        batch = {k: jnp.asarray(v) for k, v in ds.batch(i % 2).items()}
        state, metrics = step(state, batch)
        last = float(metrics["ce_loss"])
        first = first if first is not None else last
    assert last < first

    # 2. MC compression (PMQ + ODP)
    ccfg = CompressionConfig(enabled=True, target_bits=2.54, group_size=32,
                             odp_enabled=True)
    calib = jnp.asarray(calibration_batch(cfg, 4, 48))
    record = pipeline.calibrate(model, state.params, calib,
                                bit_choices=tuple(ccfg.bit_choices),
                                group_size=ccfg.group_size)
    cplan = pipeline.plan(record, ccfg, layout="uniform")
    art = pipeline.apply(model, state.params, cplan, record)
    qparams, runtime, report = art.params, art.runtime, art.report
    assert report.avg_bits <= 2.54 + 1e-9
    assert report.pmq.compression_ratio > 0.7
    assert runtime.quant_meta is not None
    assert runtime.odp is not None and 0 < runtime.odp.threshold < 1

    # 3. quality: compressed model close to fp on held-out data
    ev = jnp.asarray(SyntheticTokenDataset(SyntheticTextConfig(
        vocab_size=cfg.vocab_size, seq_len=48, global_batch=4,
        seed=99)).batch(0)["tokens"])
    ref, _, _ = model.forward(state.params, ev)
    out, _, _ = model.forward(qparams, ev, mc=runtime)
    rel = float(jnp.linalg.norm(out - ref) / jnp.linalg.norm(ref))
    assert np.isfinite(rel) and rel < 0.6, rel

    # 4. serving the compressed model generates deterministically
    eng = ServeEngine(model, qparams, batch_size=2, mc=runtime)
    reqs = [Request(uid=i, prompt=np.arange(1, 8, dtype=np.int32),
                    max_new_tokens=4) for i in range(2)]
    res = eng.run(reqs)
    assert all(r.tokens.shape == (4,) for r in res)
    res2 = eng.run(reqs)
    np.testing.assert_array_equal(res[0].tokens, res2[0].tokens)
