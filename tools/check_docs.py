"""Docs link checker: fail on broken relative links in markdown docs.

Scans ``README.md`` and ``docs/*.md`` for markdown links/images and
verifies every **relative** target exists on disk (anchors stripped;
``http(s)://``, ``mailto:`` and pure-anchor links are skipped). Used by
the ``docs-check`` CI job together with ``python -m compileall
examples/`` so documented entry points at least resolve and parse.

    python tools/check_docs.py [repo_root]
"""
from __future__ import annotations

import re
import sys
from pathlib import Path
from typing import List, Tuple

# [text](target) and ![alt](target); stops at the first unescaped ')'
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_SKIP = ("http://", "https://", "mailto:", "#")


def _strip_code(text: str) -> str:
    """Remove fenced and inline code spans (links in code are examples,
    not navigation)."""
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    return re.sub(r"`[^`\n]*`", "", text)


def doc_files(root: Path) -> List[Path]:
    out = [root / "README.md"]
    out.extend(sorted((root / "docs").glob("*.md")))
    return [p for p in out if p.exists()]


def broken_links(root: Path) -> List[Tuple[Path, str]]:
    """(file, target) for every relative link that does not resolve."""
    bad = []
    for md in doc_files(root):
        for target in _LINK.findall(_strip_code(md.read_text())):
            if target.startswith(_SKIP):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            base = root if path.startswith("/") else md.parent
            resolved = (base / path.lstrip("/")).resolve()
            if not resolved.exists():
                bad.append((md, target))
    return bad


def main(argv: List[str]) -> int:
    root = Path(argv[1]).resolve() if len(argv) > 1 else \
        Path(__file__).resolve().parents[1]
    files = doc_files(root)
    if not files:
        print(f"check_docs: no markdown docs found under {root}",
              file=sys.stderr)
        return 2
    bad = broken_links(root)
    for md, target in bad:
        print(f"check_docs: broken link in {md.relative_to(root)}: "
              f"{target}", file=sys.stderr)
    if bad:
        return 1
    print(f"check_docs: {len(files)} files OK "
          f"({', '.join(str(p.relative_to(root)) for p in files)})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
